//! Windowed embedding-MLP language model — the native-Rust Fig. 6 fallback.
//!
//! Architecture: token embeddings for a context window of `W` tokens are
//! concatenated, passed through a ReLU MLP, and projected to vocab logits.
//! All large parameters are matrices, so Muon/Shampoo preconditioning applies
//! exactly as it does to the transformer (which runs via the PJRT path).

use super::layers::{init_linear, softmax_ce, Param};
use crate::linalg::gemm::{matmul, matmul_a_bt, matmul_at_b};
use crate::linalg::Mat;
use crate::rng::Rng;

pub struct MlpLm {
    pub vocab: usize,
    pub window: usize,
    pub dim: usize,
    pub hidden: usize,
    /// vocab x dim embedding table.
    pub embed: Param,
    /// (window·dim) x hidden.
    pub w1: Param,
    /// hidden x vocab output projection.
    pub w2: Param,
    pub b1: Param,
    pub b2: Param,
}

impl MlpLm {
    pub fn new(rng: &mut Rng, vocab: usize, window: usize, dim: usize, hidden: usize) -> MlpLm {
        MlpLm {
            vocab,
            window,
            dim,
            hidden,
            embed: Param::matrix("embed", Mat::gaussian(rng, vocab, dim, 0.1)),
            w1: Param::matrix("w1", init_linear(rng, window * dim, hidden)),
            w2: Param::matrix("w2", init_linear(rng, hidden, vocab)),
            b1: Param::vector("b1", hidden),
            b2: Param::vector("b2", vocab),
        }
    }

    pub fn num_params(&self) -> usize {
        [&self.embed, &self.w1, &self.w2, &self.b1, &self.b2]
            .iter()
            .map(|p| p.numel())
            .sum()
    }

    /// Build the concatenated-embedding input for contexts.
    /// `contexts[b]` = last `window` tokens; output `B x (window·dim)`.
    fn embed_contexts(&self, contexts: &[Vec<u32>]) -> Mat {
        let b = contexts.len();
        let mut x = Mat::zeros(b, self.window * self.dim);
        for (i, ctx) in contexts.iter().enumerate() {
            assert_eq!(ctx.len(), self.window);
            for (w, &tok) in ctx.iter().enumerate() {
                let src = self.embed.w.row(tok as usize);
                let dst = &mut x.row_mut(i)[w * self.dim..(w + 1) * self.dim];
                dst.copy_from_slice(src);
            }
        }
        x
    }

    /// Forward + backward over (context → next-token) pairs.
    /// Returns mean cross-entropy (nats).
    pub fn forward_backward(&mut self, contexts: &[Vec<u32>], targets: &[u32]) -> f64 {
        let b = contexts.len();
        assert_eq!(targets.len(), b);
        let x = self.embed_contexts(contexts);
        // h = relu(x W1 + b1), logits = h W2 + b2.
        let mut pre = matmul(&x, &self.w1.w);
        for i in 0..b {
            let row = pre.row_mut(i);
            for j in 0..self.hidden {
                row[j] += self.b1.w[(0, j)];
            }
        }
        let h = super::layers::relu_forward(&pre);
        let mut logits = matmul(&h, &self.w2.w);
        for i in 0..b {
            let row = logits.row_mut(i);
            for j in 0..self.vocab {
                row[j] += self.b2.w[(0, j)];
            }
        }
        let labels: Vec<usize> = targets.iter().map(|&t| t as usize).collect();
        let (loss, dlogits, _) = softmax_ce(&logits, &labels);
        // Backward.
        self.w2.g.axpy(1.0, &matmul_at_b(&h, &dlogits));
        for i in 0..b {
            let row = dlogits.row(i);
            for j in 0..self.vocab {
                self.b2.g[(0, j)] += row[j];
            }
        }
        let dh = matmul_a_bt(&dlogits, &self.w2.w);
        let dpre = super::layers::relu_backward(&pre, &dh);
        self.w1.g.axpy(1.0, &matmul_at_b(&x, &dpre));
        for i in 0..b {
            let row = dpre.row(i);
            for j in 0..self.hidden {
                self.b1.g[(0, j)] += row[j];
            }
        }
        let dx = matmul_a_bt(&dpre, &self.w1.w);
        // Scatter-add into the embedding gradient.
        for (i, ctx) in contexts.iter().enumerate() {
            for (w, &tok) in ctx.iter().enumerate() {
                let src = &dx.row(i)[w * self.dim..(w + 1) * self.dim];
                let row = self.embed.g.row_mut(tok as usize);
                for (gj, &sj) in row.iter_mut().zip(src) {
                    *gj += sj;
                }
            }
        }
        loss
    }

    /// Evaluation loss on held-out pairs (no grads).
    pub fn eval_loss(&self, contexts: &[Vec<u32>], targets: &[u32]) -> f64 {
        let b = contexts.len();
        let x = self.embed_contexts(contexts);
        let mut pre = matmul(&x, &self.w1.w);
        for i in 0..b {
            for j in 0..self.hidden {
                pre[(i, j)] += self.b1.w[(0, j)];
            }
        }
        let h = super::layers::relu_forward(&pre);
        let mut logits = matmul(&h, &self.w2.w);
        for i in 0..b {
            for j in 0..self.vocab {
                logits[(i, j)] += self.b2.w[(0, j)];
            }
        }
        let labels: Vec<usize> = targets.iter().map(|&t| t as usize).collect();
        softmax_ce(&logits, &labels).0
    }

    pub fn zero_grads(&mut self) {
        self.embed.zero_grad();
        self.w1.zero_grad();
        self.w2.zero_grad();
        self.b1.zero_grad();
        self.b2.zero_grad();
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![
            &mut self.embed,
            &mut self.w1,
            &mut self.w2,
            &mut self.b1,
            &mut self.b2,
        ]
    }

    /// Sample LM batches from a corpus: windows of length `window` with the
    /// following token as target.
    pub fn make_batch(
        &self,
        corpus: &crate::workload::MarkovCorpus,
        rng: &mut Rng,
        batch: usize,
    ) -> (Vec<Vec<u32>>, Vec<u32>) {
        let max_start = corpus.tokens.len() - self.window - 1;
        let mut ctxs = Vec::with_capacity(batch);
        let mut tgts = Vec::with_capacity(batch);
        for _ in 0..batch {
            let s = rng.below(max_start);
            ctxs.push(corpus.tokens[s..s + self.window].to_vec());
            tgts.push(corpus.tokens[s + self.window]);
        }
        (ctxs, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::MarkovCorpus;

    #[test]
    fn shapes_and_loss_at_init() {
        let mut rng = Rng::seed_from(1);
        let mut lm = MlpLm::new(&mut rng, 32, 4, 8, 16);
        let corpus = MarkovCorpus::generate(&mut rng, 32, 2000);
        let (ctx, tgt) = lm.make_batch(&corpus, &mut rng, 8);
        lm.zero_grads();
        let loss = lm.forward_backward(&ctx, &tgt);
        // At init, loss ≈ ln(vocab).
        assert!((loss - (32f64).ln()).abs() < 1.0, "loss={loss}");
    }

    #[test]
    fn embedding_grad_matches_fd() {
        let mut rng = Rng::seed_from(2);
        let mut lm = MlpLm::new(&mut rng, 16, 3, 4, 8);
        let ctx = vec![vec![1u32, 5, 9], vec![2, 5, 0]];
        let tgt = vec![3u32, 7];
        lm.zero_grads();
        lm.forward_backward(&ctx, &tgt);
        let idx = (5usize, 2usize); // token 5 appears in both contexts
        let ana = lm.embed.g[idx];
        let h = 1e-6;
        lm.embed.w[idx] += h;
        let lp = lm.eval_loss(&ctx, &tgt);
        lm.embed.w[idx] -= 2.0 * h;
        let lm_ = lm.eval_loss(&ctx, &tgt);
        lm.embed.w[idx] += h;
        let num = (lp - lm_) / (2.0 * h);
        assert!((num - ana).abs() < 1e-4 * (1.0 + num.abs()), "{num} vs {ana}");
    }

    #[test]
    fn sgd_learns_markov_structure() {
        let mut rng = Rng::seed_from(3);
        let corpus = MarkovCorpus::generate(&mut rng, 24, 6000);
        let mut lm = MlpLm::new(&mut rng, 24, 4, 8, 32);
        let (ec, et) = lm.make_batch(&corpus, &mut rng, 64);
        let loss0 = lm.eval_loss(&ec, &et);
        for _ in 0..60 {
            let (ctx, tgt) = lm.make_batch(&corpus, &mut rng, 32);
            lm.zero_grads();
            lm.forward_backward(&ctx, &tgt);
            for p in lm.params_mut() {
                let g = p.g.clone();
                p.w.axpy(-0.3, &g);
            }
        }
        let loss1 = lm.eval_loss(&ec, &et);
        assert!(loss1 < loss0 - 0.1, "loss {loss0} -> {loss1}");
    }
}
