//! Parameters and the layer primitives (linear, ReLU, softmax cross-entropy)
//! with hand-written backward passes.

use crate::linalg::gemm::{matmul, matmul_a_bt, matmul_at_b};
use crate::linalg::Mat;
use crate::rng::Rng;

/// What kind of parameter this is — optimizers treat matrices (Muon polar,
/// Shampoo Kronecker) differently from vectors (elementwise Adam).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// 2-D weight (rows = in, cols = out).
    Matrix,
    /// Bias/gain vector stored as a 1 x n matrix.
    Vector,
}

/// A trainable tensor with its gradient accumulator.
pub struct Param {
    pub name: String,
    pub w: Mat,
    pub g: Mat,
    pub kind: ParamKind,
}

impl Param {
    pub fn matrix(name: &str, w: Mat) -> Param {
        let g = Mat::zeros(w.rows(), w.cols());
        Param { name: name.into(), w, g, kind: ParamKind::Matrix }
    }
    pub fn vector(name: &str, n: usize) -> Param {
        Param {
            name: name.into(),
            w: Mat::zeros(1, n),
            g: Mat::zeros(1, n),
            kind: ParamKind::Vector,
        }
    }
    pub fn zero_grad(&mut self) {
        self.g.as_mut_slice().iter_mut().for_each(|x| *x = 0.0);
    }
    pub fn numel(&self) -> usize {
        self.w.rows() * self.w.cols()
    }
}

/// Kaiming-ish init for a `fan_in x fan_out` weight.
pub fn init_linear(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Mat {
    Mat::gaussian(rng, fan_in, fan_out, (2.0 / fan_in as f64).sqrt())
}

/// Forward `y = x W + b`; `x: B x in`, `W: in x out`, `b: 1 x out`.
pub fn linear_forward(x: &Mat, w: &Mat, b: &Mat) -> Mat {
    let mut y = matmul(x, w);
    let out = y.cols();
    for i in 0..y.rows() {
        let row = y.row_mut(i);
        for j in 0..out {
            row[j] += b[(0, j)];
        }
    }
    y
}

/// Backward of linear: given `dy`, accumulate `dW += xᵀ dy`, `db += Σ_rows dy`
/// and return `dx = dy Wᵀ`.
pub fn linear_backward(x: &Mat, w: &Mat, dy: &Mat, dw: &mut Mat, db: &mut Mat) -> Mat {
    dw.axpy(1.0, &matmul_at_b(x, dy));
    for i in 0..dy.rows() {
        let row = dy.row(i);
        for j in 0..dy.cols() {
            db[(0, j)] += row[j];
        }
    }
    matmul_a_bt(dy, w)
}

/// ReLU forward (in place variant returns a fresh matrix for the cache).
pub fn relu_forward(x: &Mat) -> Mat {
    let mut y = x.clone();
    y.as_mut_slice().iter_mut().for_each(|v| {
        if *v < 0.0 {
            *v = 0.0;
        }
    });
    y
}

/// ReLU backward: `dx = dy ⊙ (x > 0)`.
pub fn relu_backward(x: &Mat, dy: &Mat) -> Mat {
    let mut dx = dy.clone();
    for (d, &v) in dx.as_mut_slice().iter_mut().zip(x.as_slice()) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
    dx
}

/// Softmax cross-entropy: returns (mean loss, dlogits, #correct).
/// `logits: B x C`, `labels[b] ∈ [0, C)`.
pub fn softmax_ce(logits: &Mat, labels: &[usize]) -> (f64, Mat, usize) {
    let (b, c) = logits.shape();
    assert_eq!(labels.len(), b);
    let mut dlogits = Mat::zeros(b, c);
    let mut loss = 0.0;
    let mut correct = 0;
    for i in 0..b {
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0;
        for &v in row {
            denom += (v - mx).exp();
        }
        let log_denom = denom.ln() + mx;
        let y = labels[i];
        loss += log_denom - row[y];
        // argmax
        let (mut best, mut best_v) = (0usize, f64::NEG_INFINITY);
        for (j, &v) in row.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = j;
            }
        }
        if best == y {
            correct += 1;
        }
        for j in 0..c {
            let p = (row[j] - log_denom).exp();
            dlogits[(i, j)] = (p - if j == y { 1.0 } else { 0.0 }) / b as f64;
        }
    }
    (loss / b as f64, dlogits, correct)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of a scalar function's gradient wrt one entry.
    fn fd_check(
        mut f: impl FnMut(&Mat) -> f64,
        w: &Mat,
        grad: &Mat,
        idx: (usize, usize),
        tol: f64,
    ) {
        let h = 1e-6;
        let mut wp = w.clone();
        wp[idx] += h;
        let mut wm = w.clone();
        wm[idx] -= h;
        let num = (f(&wp) - f(&wm)) / (2.0 * h);
        let ana = grad[idx];
        assert!(
            (num - ana).abs() < tol * (1.0 + num.abs()),
            "fd {num} vs analytic {ana} at {idx:?}"
        );
    }

    #[test]
    fn linear_grads_match_fd() {
        let mut rng = Rng::seed_from(1);
        let x = Mat::gaussian(&mut rng, 4, 3, 1.0);
        let w = Mat::gaussian(&mut rng, 3, 5, 1.0);
        let b = Mat::gaussian(&mut rng, 1, 5, 1.0);
        let labels = vec![0usize, 2, 4, 1];

        let loss_of = |w_: &Mat, b_: &Mat, x_: &Mat| {
            let y = linear_forward(x_, w_, b_);
            softmax_ce(&y, &labels).0
        };

        let y = linear_forward(&x, &w, &b);
        let (_, dy, _) = softmax_ce(&y, &labels);
        let mut dw = Mat::zeros(3, 5);
        let mut db = Mat::zeros(1, 5);
        let dx = linear_backward(&x, &w, &dy, &mut dw, &mut db);

        fd_check(|w_| loss_of(w_, &b, &x), &w, &dw, (1, 2), 1e-4);
        fd_check(|w_| loss_of(w_, &b, &x), &w, &dw, (0, 0), 1e-4);
        fd_check(|b_| loss_of(&w, b_, &x), &b, &db, (0, 3), 1e-4);
        fd_check(|x_| loss_of(&w, &b, x_), &x, &dx, (2, 1), 1e-4);
    }

    #[test]
    fn relu_zeroes_negatives() {
        let x = Mat::from_vec(1, 4, vec![-1.0, 2.0, 0.0, -0.5]).unwrap();
        let y = relu_forward(&x);
        assert_eq!(y.as_slice(), &[0.0, 2.0, 0.0, 0.0]);
        let dy = Mat::from_vec(1, 4, vec![1.0; 4]).unwrap();
        let dx = relu_backward(&x, &dy);
        assert_eq!(dx.as_slice(), &[0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_ce_perfect_prediction_low_loss() {
        let mut logits = Mat::zeros(2, 3);
        logits[(0, 1)] = 20.0;
        logits[(1, 0)] = 20.0;
        let (loss, _, correct) = softmax_ce(&logits, &[1, 0]);
        assert!(loss < 1e-6);
        assert_eq!(correct, 2);
    }

    #[test]
    fn softmax_grads_sum_to_zero() {
        let mut rng = Rng::seed_from(2);
        let logits = Mat::gaussian(&mut rng, 3, 5, 1.0);
        let (_, d, _) = softmax_ce(&logits, &[0, 1, 2]);
        for i in 0..3 {
            let s: f64 = d.row(i).iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn param_helpers() {
        let mut rng = Rng::seed_from(3);
        let mut p = Param::matrix("w", Mat::gaussian(&mut rng, 2, 3, 1.0));
        assert_eq!(p.numel(), 6);
        p.g[(0, 0)] = 5.0;
        p.zero_grad();
        assert_eq!(p.g[(0, 0)], 0.0);
        let v = Param::vector("b", 4);
        assert_eq!(v.kind, ParamKind::Vector);
        assert_eq!(v.w.shape(), (1, 4));
    }
}
