//! Checkpointing: a small self-describing binary format for parameter sets,
//! so long training runs (the Fig. 6 driver) can stop and resume without
//! Python or external serialization crates.
//!
//! Layout (all little-endian):
//! ```text
//! magic   8 bytes  "PRISMCK1"
//! step    u64      optimizer step the checkpoint was taken at
//! count   u64      number of parameters
//! per parameter:
//!   name_len u64, name bytes (UTF-8)
//!   kind     u8   (0 = Matrix, 1 = Vector)
//!   rows u64, cols u64
//!   data     rows·cols f64
//! checksum u64     FNV-1a over everything before it
//! ```

use super::layers::{Param, ParamKind};
use crate::linalg::Mat;
use crate::util::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PRISMCK1";

/// FNV-1a, enough to catch truncation/bit-rot — not cryptographic.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(buf: &[u8], off: &mut usize) -> Result<u64> {
    if *off + 8 > buf.len() {
        return Err(Error::Runtime("checkpoint truncated".into()));
    }
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

/// Serialize `params` (+ the optimizer step) into the checkpoint format.
pub fn encode(params: &[Param], step: u64) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u64(&mut buf, step);
    put_u64(&mut buf, params.len() as u64);
    for p in params {
        put_u64(&mut buf, p.name.len() as u64);
        buf.extend_from_slice(p.name.as_bytes());
        buf.push(match p.kind {
            ParamKind::Matrix => 0,
            ParamKind::Vector => 1,
        });
        put_u64(&mut buf, p.w.rows() as u64);
        put_u64(&mut buf, p.w.cols() as u64);
        for &v in p.w.as_slice() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let check = fnv1a(&buf);
    put_u64(&mut buf, check);
    buf
}

/// Decode a checkpoint; returns (params, step). Validates magic, checksum
/// and internal lengths.
pub fn decode(buf: &[u8]) -> Result<(Vec<Param>, u64)> {
    if buf.len() < MAGIC.len() + 24 {
        return Err(Error::Runtime("checkpoint too short".into()));
    }
    if &buf[..8] != MAGIC {
        return Err(Error::Runtime("bad checkpoint magic".into()));
    }
    let body = &buf[..buf.len() - 8];
    let mut off = buf.len() - 8;
    let want = get_u64(buf, &mut off)?;
    if fnv1a(body) != want {
        return Err(Error::Runtime("checkpoint checksum mismatch".into()));
    }
    let mut off = 8;
    let step = get_u64(body, &mut off)?;
    let count = get_u64(body, &mut off)? as usize;
    let mut params = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = get_u64(body, &mut off)? as usize;
        if off + name_len + 1 > body.len() {
            return Err(Error::Runtime("checkpoint truncated (name)".into()));
        }
        let name = std::str::from_utf8(&body[off..off + name_len])
            .map_err(|_| Error::Runtime("checkpoint name not UTF-8".into()))?
            .to_string();
        off += name_len;
        let kind = match body[off] {
            0 => ParamKind::Matrix,
            1 => ParamKind::Vector,
            k => return Err(Error::Runtime(format!("bad param kind {k}"))),
        };
        off += 1;
        let rows = get_u64(body, &mut off)? as usize;
        let cols = get_u64(body, &mut off)? as usize;
        let numel = rows
            .checked_mul(cols)
            .ok_or_else(|| Error::Runtime("checkpoint shape overflow".into()))?;
        if off + numel * 8 > body.len() {
            return Err(Error::Runtime("checkpoint truncated (data)".into()));
        }
        let mut w = Mat::zeros(rows, cols);
        for v in w.as_mut_slice() {
            *v = f64::from_le_bytes(body[off..off + 8].try_into().unwrap());
            off += 8;
        }
        let mut p = Param::matrix(&name, w);
        p.kind = kind;
        params.push(p);
    }
    Ok((params, step))
}

/// Write a checkpoint atomically (tmp file + rename).
pub fn save(path: impl AsRef<Path>, params: &[Param], step: u64) -> Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    let buf = encode(params, step);
    let mut f = std::fs::File::create(&tmp)
        .map_err(|e| Error::Runtime(format!("create {}: {e}", tmp.display())))?;
    f.write_all(&buf)
        .map_err(|e| Error::Runtime(format!("write {}: {e}", tmp.display())))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .map_err(|e| Error::Runtime(format!("rename to {}: {e}", path.display())))?;
    Ok(())
}

/// Load a checkpoint from disk.
pub fn load(path: impl AsRef<Path>) -> Result<(Vec<Param>, u64)> {
    let mut f = std::fs::File::open(path.as_ref())
        .map_err(|e| Error::Runtime(format!("open {}: {e}", path.as_ref().display())))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)
        .map_err(|e| Error::Runtime(format!("read checkpoint: {e}")))?;
    decode(&buf)
}

/// Copy checkpointed weights into an existing parameter set, matching by
/// name and validating shapes — the resume path for [`crate::coordinator::train::TrainDriver`].
pub fn restore_into(params: &mut [Param], saved: &[Param]) -> Result<()> {
    if params.len() != saved.len() {
        return Err(Error::Shape(format!(
            "checkpoint has {} params, model has {}",
            saved.len(),
            params.len()
        )));
    }
    for (p, s) in params.iter_mut().zip(saved) {
        if p.name != s.name {
            return Err(Error::Shape(format!(
                "param name mismatch: model '{}' vs checkpoint '{}'",
                p.name, s.name
            )));
        }
        if p.w.shape() != s.w.shape() {
            return Err(Error::Shape(format!(
                "param '{}': model {:?} vs checkpoint {:?}",
                p.name,
                p.w.shape(),
                s.w.shape()
            )));
        }
        p.w = s.w.clone();
        p.zero_grad();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample_params(seed: u64) -> Vec<Param> {
        let mut rng = Rng::seed_from(seed);
        vec![
            Param::matrix("w0", Mat::gaussian(&mut rng, 6, 4, 1.0)),
            Param::vector("b0", 4),
            Param::matrix("w1", Mat::gaussian(&mut rng, 4, 3, 0.5)),
        ]
    }

    #[test]
    fn roundtrip_exact() {
        let params = sample_params(1);
        let buf = encode(&params, 1234);
        let (got, step) = decode(&buf).unwrap();
        assert_eq!(step, 1234);
        assert_eq!(got.len(), 3);
        for (a, b) in params.iter().zip(&got) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.w.shape(), b.w.shape());
            assert_eq!(a.w.as_slice(), b.w.as_slice()); // bit-exact
        }
    }

    #[test]
    fn save_load_via_disk() {
        let params = sample_params(2);
        let path = std::env::temp_dir().join("prism_ckpt_test.bin");
        save(&path, &params, 7).unwrap();
        let (got, step) = load(&path).unwrap();
        assert_eq!(step, 7);
        assert_eq!(got[0].w.as_slice(), params[0].w.as_slice());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corruption_detected() {
        let params = sample_params(3);
        let mut buf = encode(&params, 1);
        let mid = buf.len() / 2;
        buf[mid] ^= 0xFF;
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn truncation_detected() {
        let params = sample_params(4);
        let buf = encode(&params, 1);
        assert!(decode(&buf[..buf.len() - 9]).is_err());
        assert!(decode(&buf[..10]).is_err());
        assert!(decode(b"").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let params = sample_params(5);
        let mut buf = encode(&params, 1);
        buf[0] = b'X';
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn restore_matches_by_name_and_shape() {
        let saved = sample_params(6);
        let mut params = sample_params(7); // same structure, different values
        restore_into(&mut params, &saved).unwrap();
        assert_eq!(params[0].w.as_slice(), saved[0].w.as_slice());

        // Name mismatch rejected.
        let mut renamed = sample_params(8);
        renamed[1].name = "other".into();
        assert!(restore_into(&mut renamed, &saved).is_err());

        // Shape mismatch rejected.
        let mut reshaped = sample_params(9);
        reshaped[0].w = Mat::zeros(2, 2);
        assert!(restore_into(&mut reshaped, &saved).is_err());

        // Count mismatch rejected.
        let mut fewer = sample_params(10);
        fewer.pop();
        assert!(restore_into(&mut fewer, &saved).is_err());
    }
}
