//! Multi-layer perceptron with explicit backprop — the Fig. 5 model
//! (substituting for ResNet-20/32 at CPU scale; see DESIGN.md).

use super::layers::{
    init_linear, linear_backward, linear_forward, relu_backward, relu_forward, softmax_ce,
    Param,
};
use crate::linalg::Mat;
use crate::rng::Rng;

/// `dims = [in, h1, ..., out]`; ReLU between layers, none after the last.
pub struct Mlp {
    pub weights: Vec<Param>,
    pub biases: Vec<Param>,
    dims: Vec<usize>,
}

impl Mlp {
    pub fn new(rng: &mut Rng, dims: &[usize]) -> Mlp {
        assert!(dims.len() >= 2);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..dims.len() - 1 {
            weights.push(Param::matrix(
                &format!("w{l}"),
                init_linear(rng, dims[l], dims[l + 1]),
            ));
            biases.push(Param::vector(&format!("b{l}"), dims[l + 1]));
        }
        Mlp { weights, biases, dims: dims.to_vec() }
    }

    pub fn num_layers(&self) -> usize {
        self.weights.len()
    }

    pub fn num_params(&self) -> usize {
        self.weights.iter().map(|p| p.numel()).sum::<usize>()
            + self.biases.iter().map(|p| p.numel()).sum::<usize>()
    }

    /// Forward pass only; returns logits.
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut h = x.clone();
        for l in 0..self.num_layers() {
            h = linear_forward(&h, &self.weights[l].w, &self.biases[l].w);
            if l + 1 < self.num_layers() {
                h = relu_forward(&h);
            }
        }
        h
    }

    /// Forward + backward; accumulates gradients into the params.
    /// Returns (mean loss, #correct).
    pub fn forward_backward(&mut self, x: &Mat, labels: &[usize]) -> (f64, usize) {
        let nl = self.num_layers();
        // Forward with caches: pre[l] = input to layer l, post[l] = pre-ReLU output.
        let mut inputs: Vec<Mat> = Vec::with_capacity(nl);
        let mut pre_relu: Vec<Mat> = Vec::with_capacity(nl);
        let mut h = x.clone();
        for l in 0..nl {
            inputs.push(h.clone());
            let y = linear_forward(&h, &self.weights[l].w, &self.biases[l].w);
            pre_relu.push(y.clone());
            h = if l + 1 < nl { relu_forward(&y) } else { y };
        }
        let (loss, mut d, correct) = softmax_ce(&h, labels);
        // Backward.
        for l in (0..nl).rev() {
            if l + 1 < nl {
                d = relu_backward(&pre_relu[l], &d);
            }
            let w = self.weights[l].w.clone(); // cheap relative to the GEMMs
            let dw_holder = &mut self.weights[l].g;
            let db_holder = &mut self.biases[l].g;
            d = linear_backward(&inputs[l], &w, &d, dw_holder, db_holder);
        }
        (loss, correct)
    }

    /// Evaluate accuracy on a batch.
    pub fn accuracy(&self, x: &Mat, labels: &[usize]) -> f64 {
        let logits = self.forward(x);
        let mut correct = 0;
        for i in 0..labels.len() {
            let row = logits.row(i);
            let (mut best, mut bv) = (0usize, f64::NEG_INFINITY);
            for (j, &v) in row.iter().enumerate() {
                if v > bv {
                    bv = v;
                    best = j;
                }
            }
            if best == labels[i] {
                correct += 1;
            }
        }
        correct as f64 / labels.len() as f64
    }

    pub fn zero_grads(&mut self) {
        for p in self.weights.iter_mut().chain(self.biases.iter_mut()) {
            p.zero_grad();
        }
    }

    /// All params (weights then biases) for an optimizer pass.
    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        self.weights.iter_mut().chain(self.biases.iter_mut()).collect()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_counts() {
        let mut rng = Rng::seed_from(1);
        let mlp = Mlp::new(&mut rng, &[8, 16, 4]);
        assert_eq!(mlp.num_layers(), 2);
        assert_eq!(mlp.num_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Mat::gaussian(&mut rng, 3, 8, 1.0);
        assert_eq!(mlp.forward(&x).shape(), (3, 4));
    }

    #[test]
    fn full_model_grad_matches_fd() {
        let mut rng = Rng::seed_from(2);
        let mut mlp = Mlp::new(&mut rng, &[5, 7, 3]);
        let x = Mat::gaussian(&mut rng, 4, 5, 1.0);
        let labels = vec![0usize, 1, 2, 0];
        mlp.zero_grads();
        let (_, _) = mlp.forward_backward(&x, &labels);
        // FD on one entry of each weight/bias.
        let h = 1e-6;
        for l in 0..2 {
            let idx = (1.min(mlp.weights[l].w.rows() - 1), 2.min(mlp.weights[l].w.cols() - 1));
            let ana = mlp.weights[l].g[idx];
            mlp.weights[l].w[idx] += h;
            let lp = {
                let logits = mlp.forward(&x);
                crate::nn::layers::softmax_ce(&logits, &labels).0
            };
            mlp.weights[l].w[idx] -= 2.0 * h;
            let lm = {
                let logits = mlp.forward(&x);
                crate::nn::layers::softmax_ce(&logits, &labels).0
            };
            mlp.weights[l].w[idx] += h;
            let num = (lp - lm) / (2.0 * h);
            assert!((num - ana).abs() < 1e-4 * (1.0 + num.abs()), "layer {l}: {num} vs {ana}");
        }
    }

    #[test]
    fn sgd_training_reduces_loss() {
        let mut rng = Rng::seed_from(3);
        let ds = crate::workload::BlobsDataset::generate(&mut rng, 128, 10, 3, 4.0);
        let mut mlp = Mlp::new(&mut rng, &[10, 32, 3]);
        let idx: Vec<usize> = (0..64).collect();
        let (x, y) = ds.batch(&idx);
        mlp.zero_grads();
        let (loss0, _) = mlp.forward_backward(&x, &y);
        // 30 plain-SGD steps.
        let mut last = loss0;
        for _ in 0..30 {
            for p in mlp.params_mut() {
                let g = p.g.clone();
                p.w.axpy(-0.1, &g);
            }
            mlp.zero_grads();
            let (l, _) = mlp.forward_backward(&x, &y);
            last = l;
        }
        assert!(last < 0.5 * loss0, "loss {loss0} -> {last}");
        assert!(mlp.accuracy(&x, &y) > 0.8);
    }
}
