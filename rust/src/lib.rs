//! # PRISM — Polynomial-fitting and Randomized Iterative Sketching for Matrix functions
//!
//! A production-quality reproduction of *"PRISM: Distribution-free Adaptive
//! Computation of Matrix Functions for Accelerating Neural Network Training"*
//! (Yang, Wang, Balabanov, Erichson, Mahoney; 2026) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate is organised in four tiers:
//!
//! 1. **Substrates** (everything built from scratch — the build environment is
//!    fully offline): [`rng`], [`threads`], [`cli`], [`configfmt`], [`ptest`],
//!    [`metrics`], [`benchkit`], [`linalg`], [`randmat`], [`workload`].
//!    The GEMM layer ([`linalg::gemm`]) is a parallel, workspace-reusing
//!    engine: row-panel dispatch over the [`threads::ThreadPool`] with
//!    bit-identical results at every pool size (`--threads` on the CLI,
//!    `service.gemm_threads` in configs), `*_into` out-parameter kernels,
//!    and a [`linalg::gemm::Workspace`] buffer pool so every iteration
//!    engine below runs allocation-free after its first iteration.
//! 2. **PRISM core**: [`sketch`] (oblivious subspace embeddings + sketched
//!    power traces), [`polyfit`] (constrained minimisation of the degree-4
//!    fitting objective `m(α)`), [`coeffs`] (closed-form coefficient
//!    assembly), and the iteration engines in [`prism`] — one per row of the
//!    paper's Table 1.
//! 3. **Baselines**: [`baselines`] — classical Newton–Schulz, PolarExpress
//!    (minimax/equioscillation), CANS-style Chebyshev acceleration, and
//!    eigendecomposition-based matrix functions.
//! 4. **The solver API**: [`matfn`] — the single public surface over every
//!    engine and baseline: a typed task + spec request, a string-keyed
//!    [`matfn::registry`] for CLI/config/service dispatch, and a stateful
//!    [`matfn::Solver`] whose cross-call workspace makes repeated same-shape
//!    solves allocation-free (warm-start and per-iteration observer hooks
//!    included).
//! 5. **Application layer**: [`optim`] (Muon, Shampoo, AdamW, SGD with
//!    pluggable matrix-function backends), [`nn`] (manual-backprop networks
//!    for the Fig. 5 experiments), [`runtime`] (PJRT loading of AOT-compiled
//!    JAX/Pallas artifacts) and [`coordinator`] (the L3 preconditioner
//!    service + training driver) — all dispatching through [`matfn`].
//!
//! ## Quick start
//!
//! ```
//! use prism::matfn::{registry, MatFnSolver};
//! use prism::{randmat, Rng};
//!
//! let mut rng = Rng::seed_from(42);
//! let a = randmat::gaussian(&mut rng, 96, 48);
//! // Plan once (any name from `registry::names()`), execute many times —
//! // the solver reuses its iteration buffers across same-shape calls.
//! let mut solver = registry::resolve("prism5-polar").unwrap();
//! let out = solver.solve(&a, &mut rng);
//! assert!(out.log.final_residual() < 1e-6);
//! ```
// Every unsafe operation must sit in an explicit `unsafe {}` block with its
// own `// SAFETY:` justification, even inside `unsafe fn` (lint rule R2 in
// `cargo xtask lint` checks the comments; this makes the blocks visible).
#![deny(unsafe_op_in_unsafe_fn)]
// Clippy runs in CI with `-D warnings`; these long-stable style lints fight
// the kernel-style index arithmetic and many-operand math signatures used
// throughout the linalg core, so they are opted out crate-wide.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]
#![allow(clippy::type_complexity)]

pub mod util;
pub mod rng;
pub mod threads;
pub mod cli;
pub mod configfmt;
pub mod config;
pub mod ptest;
pub mod metrics;
pub mod benchkit;
pub mod linalg;
pub mod randmat;
pub mod workload;
pub mod sketch;
pub mod polyfit;
pub mod coeffs;
pub mod prism;
pub mod baselines;
pub mod matfn;
pub mod optim;
pub mod nn;
pub mod runtime;
pub mod coordinator;

pub use linalg::Mat;
pub use matfn::{MatFnSolver, MatFnTask, Solver};
pub use rng::Rng;
