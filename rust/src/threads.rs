//! A small fixed-size thread pool (no rayon offline).
//!
//! Used by the coordinator's worker pool and by the GEMM engine to run
//! row-panel kernels in parallel ([`scoped`]). On the single-core CI box the
//! pool degrades gracefully to sequential execution.

use crate::runtime::sync::mpsc::{channel, Receiver, Sender};
use crate::runtime::sync::{Arc, Condvar, Mutex, PoisonError};
use crate::util::lock_or_recover;
use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with a shared queue.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    tx: Sender<Message>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(size);
        for _ in 0..size {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let guard = lock_or_recover(&rx);
                    guard.recv()
                };
                match msg {
                    Ok(Message::Run(job)) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock_or_recover(lock);
                        *p -= 1;
                        if *p == 0 {
                            cv.notify_all();
                        }
                    }
                    Ok(Message::Shutdown) | Err(_) => break,
                }
            }));
        }
        ThreadPool { workers, tx, pending }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn for_machine() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock_or_recover(lock) += 1;
        }
        self.tx.send(Message::Run(Box::new(job))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock_or_recover(lock);
        while *p > 0 {
            p = cv.wait(p).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run borrowed jobs on `pool` and block until every one has completed —
/// "scoped" execution, the primitive the parallel GEMM panels are built on.
///
/// Unlike [`ThreadPool::execute`] + [`ThreadPool::wait_idle`], completion is
/// tracked per *call*, so concurrent callers (e.g. several service workers
/// sharing one GEMM pool) never wait on each other's jobs.
///
/// Jobs may borrow from the caller's stack; this function does not return
/// until all of them have run, which is what makes the lifetime erasure in
/// the implementation sound. A panic inside any job is caught at the worker
/// (so a failed parallel kernel cannot wedge the pool) and the **original
/// payload** is re-raised here after the barrier, preserving the assertion
/// message for the test harness.
pub fn scoped<'scope>(pool: &ThreadPool, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    let total = jobs.len();
    if total == 0 {
        return;
    }
    let done = Arc::new((Mutex::new(0usize), Condvar::new()));
    let panic_slot: Arc<Mutex<Option<Box<dyn Any + Send>>>> = Arc::new(Mutex::new(None));
    for job in jobs {
        // SAFETY: only the lifetime is erased. We block on `done` below until
        // every job has finished, so borrows inside `job` cannot outlive the
        // data they reference.
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Box<dyn FnOnce() + Send + 'static>>(job)
        };
        let done = Arc::clone(&done);
        let panic_slot = Arc::clone(&panic_slot);
        pool.execute(move || {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(job)) {
                let mut slot = lock_or_recover(&panic_slot);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let (lock, cv) = &*done;
            let mut d = lock_or_recover(lock);
            *d += 1;
            cv.notify_all();
        });
    }
    let (lock, cv) = &*done;
    let mut d = lock_or_recover(lock);
    while *d < total {
        d = cv.wait(d).unwrap_or_else(PoisonError::into_inner);
    }
    drop(d);
    let payload = lock_or_recover(&panic_slot).take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
}

/// Run `f(i)` for i in 0..n across `pool`, collecting results in order.
/// Results are computed into a pre-sized buffer guarded by a mutex of slots.
pub fn parallel_map<T: Send + 'static>(
    pool: &ThreadPool,
    n: usize,
    f: impl Fn(usize) -> T + Send + Sync + 'static,
) -> Vec<T> {
    let f = Arc::new(f);
    let (tx, rx): (Sender<(usize, T)>, Receiver<(usize, T)>) = channel();
    for i in 0..n {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.execute(move || {
            let v = f(i);
            let _ = tx.send((i, v));
        });
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in rx.iter() {
        slots[i] = Some(v);
    }
    slots.into_iter().map(|s| s.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_ordered() {
        let pool = ThreadPool::new(3);
        let out = parallel_map(&pool, 20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = ThreadPool::new(1);
        let out = parallel_map(&pool, 5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn wait_idle_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert!(pool.size() == 2);
    }

    #[test]
    fn scoped_runs_borrowed_jobs() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 12];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(4)
                .enumerate()
                .map(|(b, chunk)| {
                    Box::new(move || {
                        for (i, x) in chunk.iter_mut().enumerate() {
                            *x = b * 4 + i;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            scoped(&pool, jobs);
        }
        assert_eq!(data, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_empty_is_noop() {
        let pool = ThreadPool::new(2);
        scoped(&pool, Vec::new());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scoped_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| {})];
        scoped(&pool, jobs);
        // The pool must still be usable afterwards (checked implicitly by
        // Drop joining the workers).
    }
}
