//! Random matrix generators for the paper's experiment suite.
//!
//! * Gaussian matrices with aspect ratio γ = n/m (Fig. 3, D.1, D.3),
//! * prescribed-spectrum matrices `U diag(σ) Vᵀ` for the σ_min sweeps (Fig. 1),
//! * Wishart matrices `GᵀG` (Fig. D.3),
//! * Marchenko–Pastur spectra and the HTMP (high-temperature Marchenko–
//!   Pastur; Hodgkinson et al. 2025) heavy-tailed family used in Figs. 4,
//!   D.2, D.4. We realise HTMP by mixing the MP bulk with inverse-gamma
//!   "temperature" variates: for tail parameter κ, each singular value is an
//!   MP draw scaled by `T^{1/2}` with `T ~ InvGamma(κ+1, κ)` (mean 1), so
//!   κ → ∞ recovers plain MP and small κ produces the heavy right tail seen
//!   in trained-network gradient spectra.

use crate::linalg::decomp::qr_householder;
use crate::linalg::gemm::{matmul, syrk_at_a};
use crate::linalg::Mat;
use crate::rng::Rng;

/// iid N(0, 1/m) Gaussian matrix of shape n x m (rows x cols); σ_max ≈ 1 + √γ.
pub fn gaussian(rng: &mut Rng, n: usize, m: usize) -> Mat {
    Mat::gaussian(rng, n, m, 1.0 / (m as f64).sqrt())
}

/// Haar-ish orthogonal matrix (QR of a Gaussian, sign-fixed): n x k, k <= n.
pub fn orthogonal(rng: &mut Rng, n: usize, k: usize) -> Mat {
    assert!(k <= n);
    let g = Mat::gaussian(rng, n, k, 1.0);
    let (mut q, r) = qr_householder(&g);
    // Fix signs so the distribution is Haar (diagonal of R positive).
    for j in 0..k {
        if r[(j, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

/// Rectangular matrix with prescribed singular values: `A = U diag(s) Vᵀ`,
/// shape m x n with `s.len() == n <= m`.
pub fn with_spectrum(rng: &mut Rng, m: usize, n: usize, s: &[f64]) -> Mat {
    assert!(n <= m && s.len() == n);
    let u = orthogonal(rng, m, n);
    let v = orthogonal(rng, n, n);
    let mut us = u;
    for j in 0..n {
        for i in 0..m {
            us[(i, j)] *= s[j];
        }
    }
    matmul(&us, &v.transpose())
}

/// Symmetric PSD matrix with prescribed eigenvalues.
pub fn sym_with_spectrum(rng: &mut Rng, n: usize, w: &[f64]) -> Mat {
    assert_eq!(w.len(), n);
    let q = orthogonal(rng, n, n);
    let mut qs = q.clone();
    for j in 0..n {
        for i in 0..n {
            qs[(i, j)] *= w[j];
        }
    }
    let mut a = matmul(&qs, &q.transpose());
    a.symmetrize();
    a
}

/// Log-spaced values in [lo, hi] (inclusive), length n — the σ sweeps.
pub fn logspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > 0.0 && n >= 1);
    if n == 1 {
        return vec![hi];
    }
    let (llo, lhi) = (lo.ln(), hi.ln());
    (0..n)
        .map(|i| (llo + (lhi - llo) * i as f64 / (n - 1) as f64).exp())
        .collect()
}

/// Wishart matrix `A = GᵀG / n` with `G` an n x m iid Gaussian (A is m x m).
pub fn wishart(rng: &mut Rng, n: usize, m: usize) -> Mat {
    let g = Mat::gaussian(rng, n, m, 1.0);
    let mut a = syrk_at_a(&g);
    a.scale(1.0 / n as f64);
    a
}

/// Sample `count` points from the Marchenko–Pastur squared-singular-value
/// law with ratio q = m/n ∈ (0, 1], via inverse-CDF on a numeric table.
pub fn marchenko_pastur_eigs(rng: &mut Rng, count: usize, q: f64) -> Vec<f64> {
    assert!(q > 0.0 && q <= 1.0);
    let lo = (1.0 - q.sqrt()).powi(2);
    let hi = (1.0 + q.sqrt()).powi(2);
    // Build density table and CDF.
    let grid = 512;
    let mut xs = Vec::with_capacity(grid);
    let mut cdf = Vec::with_capacity(grid);
    let mut acc = 0.0;
    for i in 0..grid {
        let x = lo + (hi - lo) * (i as f64 + 0.5) / grid as f64;
        let dens = ((hi - x) * (x - lo)).max(0.0).sqrt() / (2.0 * std::f64::consts::PI * q * x);
        acc += dens;
        xs.push(x);
        cdf.push(acc);
    }
    for c in cdf.iter_mut() {
        *c /= acc;
    }
    (0..count)
        .map(|_| {
            let u = rng.uniform();
            let idx = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(grid - 1),
            };
            xs[idx]
        })
        .collect()
}

/// HTMP (high-temperature Marchenko–Pastur) heavy-tailed singular values.
/// κ is the tail parameter: small κ = heavy tail; κ → ∞ recovers MP.
pub fn htmp_singular_values(rng: &mut Rng, count: usize, q: f64, kappa: f64) -> Vec<f64> {
    let mp = marchenko_pastur_eigs(rng, count, q);
    mp.into_iter()
        .map(|lam| {
            // Temperature T ~ InvGamma(kappa + 1, kappa), E[T] = 1.
            let t = rng.inverse_gamma(kappa + 1.0, kappa);
            (lam * t).sqrt()
        })
        .collect()
}

/// HTMP random matrix of shape n x m (n >= m): heavy-tailed singular values
/// planted on Haar singular vectors, normalised to σ_max = 1.
pub fn htmp(rng: &mut Rng, n: usize, m: usize, kappa: f64) -> Mat {
    assert!(n >= m);
    let q = m as f64 / n as f64;
    let mut s = htmp_singular_values(rng, m, q, kappa);
    let smax = s.iter().cloned().fold(0.0_f64, f64::max).max(1e-300);
    for x in s.iter_mut() {
        *x /= smax;
    }
    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
    with_spectrum(rng, n, m, &s)
}

/// Estimate the tail index of a sample by the Hill estimator on the top-k
/// order statistics (diagnostic used by tests to verify HTMP heaviness).
pub fn hill_tail_index(sample: &[f64], k: usize) -> f64 {
    let mut v: Vec<f64> = sample.iter().cloned().filter(|x| *x > 0.0).collect();
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let k = k.min(v.len().saturating_sub(1)).max(1);
    let xk = v[k];
    let mean_log: f64 = v[..k].iter().map(|x| (x / xk).ln()).sum::<f64>() / k as f64;
    1.0 / mean_log.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_at_b;
    use crate::linalg::svd::svd;

    #[test]
    fn orthogonal_is_orthogonal() {
        let mut rng = Rng::seed_from(1);
        let q = orthogonal(&mut rng, 20, 12);
        let qtq = matmul_at_b(&q, &q);
        assert!(qtq.sub(&Mat::eye(12)).max_abs() < 1e-10);
    }

    #[test]
    fn with_spectrum_has_it() {
        let mut rng = Rng::seed_from(2);
        let s_target = vec![2.0, 1.0, 0.5, 0.1];
        let a = with_spectrum(&mut rng, 10, 4, &s_target);
        let d = svd(&a);
        for i in 0..4 {
            assert!((d.s[i] - s_target[i]).abs() < 1e-8, "s[{i}]={}", d.s[i]);
        }
    }

    #[test]
    fn sym_with_spectrum_eigs() {
        let mut rng = Rng::seed_from(3);
        let w = vec![0.1, 1.0, 3.0];
        let a = sym_with_spectrum(&mut rng, 3, &w);
        let e = crate::linalg::eigen::symmetric_eigen(&a);
        for i in 0..3 {
            assert!((e.values[i] - w[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn logspace_endpoints() {
        let v = logspace(1e-3, 1.0, 4);
        assert!((v[0] - 1e-3).abs() < 1e-12);
        assert!((v[3] - 1.0).abs() < 1e-12);
        assert!(v.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn gaussian_sigma_max_near_mp_edge() {
        let mut rng = Rng::seed_from(4);
        let (n, m) = (120, 60); // gamma = 2
        let a = gaussian(&mut rng, n, m);
        let d = svd(&a);
        let edge = 1.0 + (n as f64 / m as f64).sqrt(); // rows scaled by 1/sqrt(m)
        assert!((d.s[0] - edge).abs() / edge < 0.25, "smax={} edge={edge}", d.s[0]);
    }

    #[test]
    fn wishart_is_psd() {
        let mut rng = Rng::seed_from(5);
        let a = wishart(&mut rng, 30, 15);
        let e = crate::linalg::eigen::symmetric_eigen(&a);
        assert!(e.values.iter().all(|&w| w > -1e-10));
    }

    #[test]
    fn mp_eigs_in_support() {
        let mut rng = Rng::seed_from(6);
        let q: f64 = 0.5;
        let lo = (1.0 - q.sqrt()).powi(2);
        let hi = (1.0 + q.sqrt()).powi(2);
        for lam in marchenko_pastur_eigs(&mut rng, 500, q) {
            assert!(lam >= lo - 1e-9 && lam <= hi + 1e-9);
        }
    }

    #[test]
    fn htmp_small_kappa_heavier_tail() {
        let mut rng = Rng::seed_from(7);
        let heavy = htmp_singular_values(&mut rng, 3000, 0.5, 0.1);
        let light = htmp_singular_values(&mut rng, 3000, 0.5, 100.0);
        // Heavy tail => smaller Hill index.
        let hi_heavy = hill_tail_index(&heavy, 150);
        let hi_light = hill_tail_index(&light, 150);
        assert!(
            hi_heavy < hi_light,
            "hill heavy={hi_heavy:.2} light={hi_light:.2}"
        );
        // And a much larger max/median ratio.
        let ratio = |v: &[f64]| {
            let mut s = v.to_vec();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[s.len() - 1] / s[s.len() / 2]
        };
        assert!(ratio(&heavy) > 2.0 * ratio(&light));
    }

    #[test]
    fn htmp_matrix_normalised() {
        let mut rng = Rng::seed_from(8);
        let a = htmp(&mut rng, 40, 20, 0.5);
        let d = svd(&a);
        assert!((d.s[0] - 1.0).abs() < 1e-8);
    }
}
