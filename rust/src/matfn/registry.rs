//! String-keyed solver registry: `"<method>-<task>"` → [`Solver`].
//!
//! This is the dispatch surface for CLI flags, TOML configs and the
//! coordinator service. A key's *method* half reuses the vocabulary of
//! [`crate::config::Backend::parse`] (`ns`, `prism3`, `prism5`, `pe`,
//! `eigen`, `newton`, …) extended with the solver families that are not
//! optimizer backends (`cans`, `cheb`, `invnewton`, classic variants); the
//! *task* half is a [`MatFnTask`] token (`polar`, `rectpolar`, `sign`,
//! `sqrt`, `invsqrt`, `invrootN`, `inverse`).
//!
//! [`resolve`] also accepts aliases (`"polar-express-polar"`,
//! `"classic-sqrt"`, any odd `"prismN"`, any `"invrootN"`); [`names`] lists
//! the canonical keys, and unknown keys produce an error that enumerates
//! them.

use super::{MatFnTask, Solver, SolverSpec};
use crate::util::{Error, Result};

/// Canonical registry keys: every entry resolves, and for each the resolved
/// solver's [`Solver::name`] equals the key (asserted by the round-trip
/// tests).
pub const NAMES: &[&str] = &[
    // polar (Muon's primitive; Figs. 1, 3, 4)
    "ns-polar",
    "prism3-polar",
    "prism5-polar",
    "prism-exact-polar",
    "pe-polar",
    "cans-polar",
    "eigen-polar",
    // rectangular polar (Gram / range-finder routes; Muon's rectangular
    // primitive — see `matfn::rect`)
    "ns-rectpolar",
    "prism3-rectpolar",
    "prism5-rectpolar",
    "eigen-rectpolar",
    // sign (§4 case study)
    "ns-sign",
    "prism3-sign",
    "prism5-sign",
    "prism-exact-sign",
    "eigen-sign",
    // sqrt (Figs. D.3–D.5)
    "ns-sqrt",
    "prism3-sqrt",
    "prism5-sqrt",
    "newton-sqrt",
    "newton-classic-sqrt",
    "pe-sqrt",
    "eigen-sqrt",
    // inverse sqrt (Shampoo's primitive; Fig. 5)
    "ns-invsqrt",
    "prism3-invsqrt",
    "prism5-invsqrt",
    "newton-invsqrt",
    "newton-classic-invsqrt",
    "invnewton-invsqrt",
    "invnewton-classic-invsqrt",
    "pe-invsqrt",
    "eigen-invsqrt",
    // general inverse roots (Table 1 row 5)
    "invnewton-invroot2",
    "invnewton-classic-invroot2",
    "invnewton-invroot4",
    "eigen-invroot2",
    "eigen-invroot4",
    // inverse (Table 1 row 7)
    "cheb-inverse",
    "cheb-classic-inverse",
    "invnewton-inverse",
    "eigen-inverse",
];

/// The canonical registry keys.
pub fn names() -> &'static [&'static str] {
    NAMES
}

fn unknown(name: &str) -> Error {
    Error::Parse(format!(
        "unknown matfn solver '{name}' (want <method>-<task>); valid names: {}",
        NAMES.join(", ")
    ))
}

fn parse_task(tok: &str) -> Option<MatFnTask> {
    match tok {
        "polar" => Some(MatFnTask::Polar),
        "rectpolar" => Some(MatFnTask::RectPolar),
        "sign" => Some(MatFnTask::Sign),
        "sqrt" => Some(MatFnTask::Sqrt),
        "invsqrt" => Some(MatFnTask::InvSqrt),
        "inverse" | "inv" => Some(MatFnTask::Inverse),
        t if t.starts_with("invroot") => {
            let rest = &t["invroot".len()..];
            if rest.is_empty() {
                Some(MatFnTask::InvRoot { p: 2 })
            } else {
                rest.parse::<usize>().ok().filter(|&p| p >= 1).map(|p| MatFnTask::InvRoot { p })
            }
        }
        _ => None,
    }
}

fn parse_method(tok: &str) -> Option<SolverSpec> {
    match tok {
        "ns" | "classic" | "newton-schulz" | "newton_schulz" => Some(SolverSpec::ns_classic(2)),
        "prism-exact" => Some(SolverSpec::prism_exact(2)),
        "newton" | "prism-newton" | "prismnewton" | "db-newton" => {
            Some(SolverSpec::db_newton(true))
        }
        "newton-classic" | "db-newton-classic" => Some(SolverSpec::db_newton(false)),
        "cheb" | "chebyshev" => Some(SolverSpec::chebyshev(true)),
        "cheb-classic" | "chebyshev-classic" => Some(SolverSpec::chebyshev(false)),
        "invnewton" | "inverse-newton" => Some(SolverSpec::inverse_newton(true)),
        "invnewton-classic" => Some(SolverSpec::inverse_newton(false)),
        "pe" | "polar-express" | "polarexpress" => Some(SolverSpec::polar_express()),
        "cans" => Some(SolverSpec::cans()),
        "eigen" | "eig" | "svd" => Some(SolverSpec::eigen()),
        t if t.starts_with("prism") => {
            // Accept both "prismN" and the Backend::name form "prism-N".
            let rest = t["prism".len()..].trim_start_matches('-');
            if rest.is_empty() {
                Some(SolverSpec::prism(2))
            } else {
                // Odd order 2d+1 ≥ 3 → degree d.
                rest.parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 3 && n % 2 == 1)
                    .map(|n| SolverSpec::prism((n - 1) / 2))
            }
        }
        _ => None,
    }
}

/// Resolve a `"<method>-<task>"` key into a planned [`Solver`]. Unknown keys
/// name the offender and list every valid canonical name; method/task pairs
/// the method cannot serve surface [`Solver::new`]'s validation error.
pub fn resolve(name: &str) -> Result<Solver> {
    let s = name.trim().to_ascii_lowercase();
    let (mtok, ttok) = s.rsplit_once('-').ok_or_else(|| unknown(name))?;
    let task = parse_task(ttok).ok_or_else(|| unknown(name))?;
    let spec = parse_method(mtok).ok_or_else(|| unknown(name))?;
    Solver::new(task, spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_canonical_name_round_trips() {
        for &name in names() {
            let s = resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(s.name(), name, "canonical name must round-trip");
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_solvers() {
        for (alias, canon) in [
            ("polar-express-polar", "pe-polar"),
            ("classic-polar", "ns-polar"),
            ("newton-schulz-polar", "ns-polar"),
            ("prism-polar", "prism5-polar"),
            ("prism-newton-sqrt", "newton-sqrt"),
            ("eig-invsqrt", "eigen-invsqrt"),
            ("svd-polar", "eigen-polar"),
            ("chebyshev-inverse", "cheb-inverse"),
            ("eigen-invroot", "eigen-invroot2"), // bare invroot defaults to p = 2
            ("PRISM5-Polar", "prism5-polar"),    // case-insensitive
        ] {
            // The first component of the tuple may itself contain '-', which
            // is exactly what the last-dash split must handle.
            let s = resolve(alias).unwrap_or_else(|e| panic!("{alias}: {e}"));
            let c = resolve(canon).unwrap();
            assert_eq!(s.name(), c.name(), "{alias} != {canon}");
        }
    }

    #[test]
    fn generalized_orders_parse() {
        assert_eq!(resolve("prism7-polar").unwrap().spec().d, 3);
        assert_eq!(resolve("invnewton-invroot3").unwrap().name(), "invnewton-invroot3");
        assert!(resolve("prism4-polar").is_err(), "even order is not a NS iteration");
        assert!(resolve("eigen-invroot0").is_err(), "p = 0 is rejected");
    }

    #[test]
    fn unknown_name_lists_valid_options() {
        for bad in ["florb", "florb-polar", "prism5-florb", "prism5"] {
            let msg = resolve(bad).unwrap_err().to_string();
            assert!(msg.contains(bad), "{msg}");
            assert!(msg.contains("prism5-polar"), "error must list valid names: {msg}");
            assert!(msg.contains("cheb-inverse"), "error must list valid names: {msg}");
        }
    }

    #[test]
    fn incompatible_pair_is_a_method_error_not_unknown() {
        let msg = resolve("cans-sqrt").unwrap_err().to_string();
        assert!(msg.contains("cannot compute"), "{msg}");
    }
}
