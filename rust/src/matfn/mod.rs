//! # `matfn` — the unified matrix-function solver API
//!
//! One request/plan/execute surface over every iteration engine in the
//! crate: the six PRISM engines (Table 1 of the paper) *and* the baselines
//! (PolarExpress, CANS, eigendecomposition) are reachable through a single
//! typed entry point, so CLI flags, TOML configs, the coordinator service
//! and the optimizers all dispatch the same way.
//!
//! The three pieces:
//!
//! * **Request** — a [`MatFnTask`] (*what* to compute: `A^{1/2}`, `A^{-1/p}`,
//!   the polar factor, …) plus a [`SolverSpec`] (*how*: method, degree,
//!   [`AlphaMode`], [`StopRule`]).
//! * **Plan** — [`Solver::new`] validates the (task, method) pair and builds
//!   a stateful [`Solver`]; [`registry::resolve`] does the same from a
//!   string key like `"prism5-polar"`, for config/CLI/service dispatch.
//! * **Execute** — [`MatFnSolver::solve`] runs the iteration. The solver
//!   **owns its ping-pong buffers** (a [`crate::linalg::gemm::Workspace`])
//!   and reuses them across calls, so from the second same-shape call onward
//!   the hot loop performs zero heap allocations — exactly the
//!   Shampoo/Muon pattern of calling the same function on same-shaped
//!   matrices thousands of times. [`MatFnSolver::solve_from`] warm-starts
//!   from a previous result (paper §C), [`Solver::solve_batch`] runs a
//!   same-shape batch in lockstep with **one shared sketch fill per
//!   iteration** (bit-identical to sequential solves at the same per-job
//!   RNG stream — the coordinator service's amortised path), and
//!   [`MatFnSolver::set_observer`] streams per-iteration residuals instead
//!   of waiting for the final [`IterationLog`].
//!
//! ## Rectangular polar: `RectPolar` and the route contract
//!
//! [`MatFnTask::RectPolar`] computes the same polar factor as
//! [`MatFnTask::Polar`] but is planned for rectangular operands; the
//! [`SolverSpec`]'s [`RectStrategy`] (default `Auto`) picks the route:
//!
//! * **`Auto`** — Gram route when `max(m,n) ≥ 2·min(m,n)`, the direct
//!   rectangular iteration otherwise (so on near-square and square inputs a
//!   `rectpolar` solver behaves exactly like its `polar` twin). `Auto`
//!   never picks the range finder — rank is not visible in a shape.
//! * **Gram** — `G = AᵀA` (or `AAᵀ`, whichever is p×p with p = min(m,n))
//!   via SYRK, the coupled PRISM sqrt/inv-sqrt engine on `G`, one skinny
//!   GEMM `A·G^{-1/2}` (or `G^{-1/2}·A`). O(p²·max(m,n)) one-off + O(p³)
//!   per iteration, vs O(p²·max(m,n)) *per iteration* for direct. Since
//!   κ(G) = κ(A)², the f64 route holds the 1e-8 conformance bar for
//!   κ(A) ≲ 1e3 (the optimizer-gradient regime); `Precision::Mixed` holds
//!   1e-4 under the same conditions. Rank-deficient inputs make `G`
//!   singular — use the range finder for those.
//! * **`RangeFinder { rank }`** — for genuinely low-rank updates: Gaussian
//!   sketch, orthonormalize, polar-solve the small core, expand
//!   ([`crate::prism::lowrank`]). Exact when `rank ≥ rank(A)`; the result
//!   is the partial isometry supported on range(A) (it does **not**
//!   fabricate null-space directions, so it differs from an SVD polar
//!   factor on rank-deficient inputs — by design). Always f64.
//!
//! Registry keys: `ns-rectpolar`, `prism3-rectpolar`, `prism5-rectpolar`,
//! `eigen-rectpolar`. Warm starts (`solve_from`) apply only when the
//! resolved route is Direct; the Gram/range cores solve in a different
//! space and ignore `x0`.
//!
//! ## Quickstart
//!
//! ```
//! use prism::matfn::{registry, MatFnSolver};
//! use prism::{randmat, Rng};
//!
//! let mut rng = Rng::seed_from(42);
//! let a = randmat::gaussian(&mut rng, 96, 48);
//! let mut solver = registry::resolve("prism5-polar").unwrap();
//! let out = solver.solve(&a, &mut rng);        // cold call: allocates buffers
//! assert!(out.log.final_residual() < 1e-6);
//! let allocs = solver.workspace_allocations();
//! let _ = solver.solve(&a, &mut rng);          // warm call: zero allocations
//! assert_eq!(solver.workspace_allocations(), allocs);
//! ```

mod batch;
pub mod rect;
pub mod registry;
mod solver;

pub use rect::RectStrategy;
pub use solver::Solver;
pub(crate) use solver::validate_input;

use crate::linalg::Mat;
use crate::prism::driver::{AlphaMode, IterEvent, IterationLog, StopRule};
use crate::rng::Rng;

/// *What* to compute — one variant per matrix function the repo serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatFnTask {
    /// `A^{1/2}` for SPD `A` (coupled methods also return `A^{-1/2}`).
    Sqrt,
    /// `A^{-1/2}` for SPD `A` — Shampoo's preconditioner root.
    InvSqrt,
    /// `A^{-1/p}` for SPD `A`, `p ≥ 1`.
    InvRoot { p: usize },
    /// The polar factor `U Vᵀ` (any orientation) — Muon's primitive.
    Polar,
    /// The polar factor routed for rectangular/low-rank operands via
    /// [`RectStrategy`] (module docs above) — Muon's rectangular primitive.
    RectPolar,
    /// `sign(A)` for `A` with `A²` symmetric.
    Sign,
    /// `A⁻¹` for full-rank `A`.
    Inverse,
}

impl MatFnTask {
    /// Canonical task token used in registry keys (`"invroot4"`, `"polar"`).
    pub fn name(&self) -> String {
        match self {
            MatFnTask::Sqrt => "sqrt".into(),
            MatFnTask::InvSqrt => "invsqrt".into(),
            MatFnTask::InvRoot { p } => format!("invroot{p}"),
            MatFnTask::Polar => "polar".into(),
            MatFnTask::RectPolar => "rectpolar".into(),
            MatFnTask::Sign => "sign".into(),
            MatFnTask::Inverse => "inverse".into(),
        }
    }
}

/// *How* to compute it — the iteration family and its knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Newton–Schulz family (polar/sign/coupled-sqrt); classic or PRISM
    /// depending on the [`AlphaMode`].
    NewtonSchulz,
    /// Coupled inverse Newton for `A^{-1/p}` (Table 1 row 5).
    InverseNewton,
    /// Denman–Beavers product-form Newton for the square root (row 6).
    DbNewton,
    /// Chebyshev iteration for the inverse (row 7).
    Chebyshev,
    /// PolarExpress minimax polynomials (baseline, σ_min = 1e-3 tuning).
    PolarExpress,
    /// CANS-style rescaled Newton–Schulz (baseline).
    Cans,
    /// Exact eigendecomposition / SVD (baseline and oracle).
    Eigen,
}

/// Arithmetic precision of the solve's hot loop.
///
/// ## The mixed-precision accuracy contract
///
/// `Mixed` runs the Newton–Schulz update GEMMs, the update-polynomial
/// assembly and the sketched α trace propagation in **f32** (twice the SIMD
/// lanes per register), while a full **f64 guard** retains an exactly-upcast
/// copy of the iterate and recomputes the residual `R` in f64 after every
/// step. *Every* stopping decision — convergence, divergence, NaN,
/// f32-floor stall — reads only that f64 residual, so the reported
/// [`IterationLog`] carries f64-grade residuals and the `converged` flag
/// means the same thing it means under `F64`. Once the f32 phase reaches
/// `max(tol, 1e-5)` or its round-off floor, one optional full-f64 cleanup
/// iteration closes the remaining gap (one NS step contracts roughly
/// quadratically, which covers the typical f32 floor for service-sized
/// inputs). See [`crate::prism::mixed`] for the driver itself.
///
/// `Mixed` applies to the Newton–Schulz family (polar / sign-free tasks:
/// `Polar`, `Sqrt`, `InvSqrt`) with `d ≤ 2`; any other method, task or
/// degree silently runs in full f64 — correctness first, speed second.
/// Results are **not** bit-identical to `F64` solves (different arithmetic),
/// but consume the identical RNG stream: sketches are drawn in f64 and
/// downcast, so per-job reproducibility and batch/solo stream alignment are
/// preserved. Keep `F64` when downstream logic compares iterates bit-wise
/// across precision settings or when `tol` is tighter than one f64 cleanup
/// step can reach from ~1e-5 (harsher than ~1e-11 for moderate sizes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Everything in f64 — the default, bit-compatible with all prior runs.
    F64,
    /// f32 iterate + f64 guard + one f64 cleanup iteration (contract above).
    Mixed,
}

impl Precision {
    /// Canonical token used in configs and CLI flags.
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }

    /// Parse a config/CLI token (`"f64"` | `"mixed"`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" => Some(Precision::F64),
            "mixed" => Some(Precision::Mixed),
            _ => None,
        }
    }
}

/// A full solver specification: method, degree `d` (Newton–Schulz order
/// `2d+1`), α-selection mode, stopping rule, the Muon warm-α phase
/// length (paper §C; 0 disables it), the hot-loop [`Precision`], and the
/// [`RectStrategy`] used by [`MatFnTask::RectPolar`] solves (ignored by
/// every other task).
#[derive(Debug, Clone, Copy)]
pub struct SolverSpec {
    pub method: Method,
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
    pub warm_iters: usize,
    pub precision: Precision,
    pub rect: RectStrategy,
}

impl SolverSpec {
    fn base(method: Method) -> SolverSpec {
        SolverSpec {
            method,
            d: 2,
            alpha: AlphaMode::Sketched { p: 8 },
            stop: StopRule::default(),
            warm_iters: 0,
            precision: Precision::F64,
            rect: RectStrategy::Auto,
        }
    }

    /// PRISM Newton–Schulz of order `2d+1` with the default sketch (p = 8).
    pub fn prism(d: usize) -> SolverSpec {
        SolverSpec { d, ..Self::base(Method::NewtonSchulz) }
    }
    /// Classical Newton–Schulz of order `2d+1` (fixed Taylor coefficients).
    pub fn ns_classic(d: usize) -> SolverSpec {
        SolverSpec { d, alpha: AlphaMode::Classic, ..Self::base(Method::NewtonSchulz) }
    }
    /// PRISM with exact O(n³) traces (ablation).
    pub fn prism_exact(d: usize) -> SolverSpec {
        SolverSpec { d, alpha: AlphaMode::Exact, ..Self::base(Method::NewtonSchulz) }
    }
    /// DB-Newton; `prism` selects the exact O(n²) α fit vs. classical α = ½.
    pub fn db_newton(prism: bool) -> SolverSpec {
        let alpha = if prism { AlphaMode::Exact } else { AlphaMode::Classic };
        SolverSpec { alpha, ..Self::base(Method::DbNewton) }
    }
    /// Chebyshev inverse; `prism` selects the sketched α fit vs. α = 1.
    pub fn chebyshev(prism: bool) -> SolverSpec {
        let alpha = if prism { AlphaMode::Sketched { p: 8 } } else { AlphaMode::Classic };
        SolverSpec { alpha, ..Self::base(Method::Chebyshev) }
    }
    /// Coupled inverse Newton; `prism` selects the sketched α fit vs. α = 1/p.
    pub fn inverse_newton(prism: bool) -> SolverSpec {
        let alpha = if prism { AlphaMode::Sketched { p: 8 } } else { AlphaMode::Classic };
        SolverSpec { alpha, ..Self::base(Method::InverseNewton) }
    }
    /// PolarExpress with the paper's σ_min = 1e-3 schedule.
    pub fn polar_express() -> SolverSpec {
        Self::base(Method::PolarExpress)
    }
    /// CANS-style rescaled classical Newton–Schulz.
    pub fn cans() -> SolverSpec {
        Self::base(Method::Cans)
    }
    /// Exact eigendecomposition / SVD.
    pub fn eigen() -> SolverSpec {
        Self::base(Method::Eigen)
    }

    pub fn with_stop(mut self, stop: StopRule) -> SolverSpec {
        self.stop = stop;
        self
    }
    pub fn with_alpha(mut self, alpha: AlphaMode) -> SolverSpec {
        self.alpha = alpha;
        self
    }
    pub fn with_warm_iters(mut self, warm_iters: usize) -> SolverSpec {
        self.warm_iters = warm_iters;
        self
    }
    /// Select the hot-loop precision (see [`Precision`] for the contract).
    pub fn with_precision(mut self, precision: Precision) -> SolverSpec {
        self.precision = precision;
        self
    }
    /// Select the [`MatFnTask::RectPolar`] route (module docs above);
    /// ignored by every other task.
    pub fn with_rect_strategy(mut self, rect: RectStrategy) -> SolverSpec {
        self.rect = rect;
        self
    }
}

/// Result of one solve: the requested function value, a coupled by-product
/// when the method computes one for free (e.g. `A^{-1/2}` alongside
/// `A^{1/2}`), and the full iteration log.
#[derive(Debug)]
pub struct MatFnOutput {
    pub primary: Mat,
    pub secondary: Option<Mat>,
    pub log: IterationLog,
}

impl MatFnOutput {
    /// True when the solve cannot be trusted: the iteration log reports
    /// divergence (non-finite or exploding residual) or the primary result
    /// itself carries non-finite entries. This is the trigger for the
    /// service's retry-with-escalation ladder.
    pub fn is_failure(&self) -> bool {
        self.log.diverged || self.primary.has_non_finite()
    }
}

/// Boxed per-iteration callback installed via [`MatFnSolver::set_observer`].
pub type BoxObserver = Box<dyn FnMut(&IterEvent) + Send>;

/// The trait every solver — PRISM engine or baseline — is served through.
///
/// `solve` takes `&mut self` because a solver owns its cross-call workspace;
/// reusing one solver for a stream of same-shape inputs is the intended
/// (and fastest) usage.
pub trait MatFnSolver {
    /// The task this solver was planned for.
    fn task(&self) -> MatFnTask;

    /// Registry-style name, e.g. `"prism5-polar"`. For every registered
    /// configuration, `registry::resolve(self.name())` rebuilds an
    /// equivalent solver.
    fn name(&self) -> String;

    /// Compute the matrix function of `a`.
    fn solve(&mut self, a: &Mat, rng: &mut Rng) -> MatFnOutput;

    /// Warm-start from `x0`, a previous result for the same or a nearby
    /// input (paper §C). Semantics differ by engine family:
    ///
    /// * **Chebyshev inverse / inverse Newton** re-reference `a` every
    ///   iteration, so this is a true warm start: the iteration polishes
    ///   `x0` *towards the new input's* answer (re-solving after a small
    ///   drift takes a couple of iterations instead of a full run).
    /// * **Polar / sign** (Newton–Schulz family and the polar baselines)
    ///   are self-contained in the iterate — the input enters only through
    ///   `X₀` — so `solve_from` orthogonally polishes `x0` itself. That is
    ///   exact when `a` is the matrix that produced `x0` and a first-order
    ///   approximation (error `O(‖ΔA‖)`) under drift — the optimizer-step
    ///   trade Muon makes when gradients barely change between steps.
    /// * **Coupled square-root methods** cannot resume from `X` alone and
    ///   fall back to a cold [`MatFnSolver::solve`].
    fn solve_from(&mut self, a: &Mat, x0: &Mat, rng: &mut Rng) -> MatFnOutput {
        let _ = x0;
        self.solve(a, rng)
    }

    /// Install (`Some`) or remove (`None`) a per-iteration observer; the
    /// coordinator service uses this to stream residual trajectories while a
    /// job is still running.
    fn set_observer(&mut self, observer: Option<BoxObserver>) {
        let _ = observer;
    }
}
