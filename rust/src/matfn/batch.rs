//! Lockstep batched execution of the Newton–Schulz-family engines — the
//! shared-sketch path behind [`Solver::solve_batch`].
//!
//! A batch of same-shape, same-task jobs advances one iteration at a time,
//! all members together. Per iteration the batch performs **one** sketch
//! fill (`S` is drawn independently of every input, so all members may read
//! the same draw without bias), then each live member runs its own trace
//! propagation, α fit, polynomial update and residual refresh — per-job
//! state (the iterate panels and the residual) stays per-job, everything
//! else (the sketch, the trace row, the update polynomial `g`, `R²` and the
//! ping-pong spare) is shared scratch from the solver's single
//! [`Workspace`]. Sketch fills per batch therefore scale with the longest
//! member's iteration count, not with `batch × iters`.
//!
//! **Bit-identity contract** (pinned by the matfn and service conformance
//! tests): member `j`'s output — iterate, α sequence, residual trajectory,
//! converged/diverged flags — is bitwise identical to a sequential
//! [`Solver::solve`] of the same input from a clone of the batch's entry
//! RNG state. This holds because a member's RNG consumption is exactly one
//! sketch fill per iteration it is live for, liveness is monotone (a member
//! that stops never resumes), and the shared fill at lockstep iteration `t`
//! is the `(t+1)`-th fill of the common stream — precisely the fill a
//! sequential run of that member would draw at its iteration `t`.

use super::{BoxObserver, MatFnOutput, MatFnTask, Solver};
use crate::coeffs::traces_needed;
use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::prism::driver::{AlphaMode, IterEvent, RunRecorder};
use crate::prism::fit::{alpha_from_traces, alpha_with_sketch, taylor_alpha, update_poly_into};
use crate::rng::Rng;
use crate::sketch::{exact_power_traces, SketchKind};
use crate::util::Stopwatch;

/// Entry point used by [`Solver::solve_batch`] for Newton–Schulz specs
/// without a warm-α phase. `inputs` is non-empty and shape-checked by the
/// caller.
pub(super) fn ns_solve_batch(
    solver: &mut Solver,
    inputs: &[&Mat],
    rng: &mut Rng,
) -> Vec<MatFnOutput> {
    match solver.task {
        MatFnTask::Polar => {
            let (m, n) = inputs[0].shape();
            if m < n {
                // Wide inputs run the native tall iteration on transposed
                // panels, exactly like the sequential engine.
                let ats: Vec<Mat> = inputs
                    .iter()
                    .map(|a| {
                        let mut t = solver.ws.take(n, m);
                        a.transpose_into(&mut t);
                        t
                    })
                    .collect();
                let refs: Vec<&Mat> = ats.iter().collect();
                let mut outs = polar_batch(solver, &refs, rng);
                for out in outs.iter_mut() {
                    out.primary = out.primary.transpose();
                }
                for t in ats {
                    solver.ws.put(t);
                }
                outs
            } else {
                polar_batch(solver, inputs, rng)
            }
        }
        MatFnTask::Sign => sign_batch(solver, inputs, rng),
        MatFnTask::Sqrt | MatFnTask::InvSqrt => sqrt_batch(solver, inputs, rng),
        _ => unreachable!("validated: Newton–Schulz serves polar/sign/sqrt/invsqrt"),
    }
}

/// Shared α-fit scratch: one sketch panel and one trace row serve the whole
/// batch. [`FitScratch::next_iteration`] performs the per-iteration shared
/// fill; [`FitScratch::alpha`] runs one member's fit against it.
struct FitScratch {
    mode: AlphaMode,
    d: usize,
    /// `(S: p×n, traces: 1×q)` for the sketched modes, `None` otherwise.
    sketch: Option<(Mat, Mat)>,
}

impl FitScratch {
    fn new(mode: AlphaMode, d: usize, n: usize, ws: &mut Workspace) -> FitScratch {
        let sketch = match mode {
            AlphaMode::Sketched { p } | AlphaMode::SketchedKind { p, .. } => {
                Some((ws.take(p, n), ws.take(1, traces_needed(d))))
            }
            _ => None,
        };
        FitScratch { mode, d, sketch }
    }

    fn kind(&self) -> SketchKind {
        match self.mode {
            AlphaMode::SketchedKind { kind, .. } => kind,
            _ => SketchKind::Gaussian,
        }
    }

    /// One shared sketch draw for this lockstep iteration (no-op for
    /// non-sketched modes, which consume no randomness).
    fn next_iteration(&mut self, rng: &mut Rng) {
        let kind = self.kind();
        if let Some((s, _)) = self.sketch.as_mut() {
            kind.fill(s, rng);
        }
    }

    /// α for one member's residual `r`. The sketched arms go through
    /// [`alpha_with_sketch`] — the same fill-independent core the
    /// sequential `prism::fit::select_alpha_ns` uses — so the batched and
    /// sequential fits cannot drift apart; the remaining arms are the same
    /// trivial one-liners (`taylor_alpha` / fixed / exact traces).
    fn alpha(&mut self, r: &Mat, eng: &GemmEngine, ws: &mut Workspace) -> f64 {
        match self.mode {
            AlphaMode::Classic => taylor_alpha(self.d),
            AlphaMode::Fixed(a) => a,
            AlphaMode::Exact => {
                alpha_from_traces(&exact_power_traces(r, traces_needed(self.d)), self.d)
            }
            AlphaMode::Sketched { .. } | AlphaMode::SketchedKind { .. } => {
                let (s, t) = self.sketch.as_mut().expect("sketched mode has scratch");
                alpha_with_sketch(s, r, self.d, t.as_mut_slice(), eng, ws)
            }
        }
    }

    fn release(self, ws: &mut Workspace) {
        if let Some((s, t)) = self.sketch {
            ws.put(s);
            ws.put(t);
        }
    }
}

/// Fire the solver-level observer for one member's completed iteration.
/// Lockstep recorders run observer-less (B recorders cannot share one
/// `&mut` observer), so events are emitted here with the member index
/// stamped on [`IterEvent::job`].
fn notify(
    observer: &mut Option<BoxObserver>,
    job: usize,
    rec: &RunRecorder<'_>,
    alpha: f64,
    residual: f64,
    elapsed_s: f64,
) {
    if let Some(obs) = observer.as_mut() {
        obs(&IterEvent { iter: rec.log.alphas.len() - 1, alpha, residual, elapsed_s, job });
    }
}

/// Lockstep polar batch (tall orientation, m ≥ n): the batched form of
/// `prism::polar::polar_prism_in`'s loop.
fn polar_batch(solver: &mut Solver, inputs: &[&Mat], rng: &mut Rng) -> Vec<MatFnOutput> {
    let b = inputs.len();
    let (m, n) = inputs[0].shape();
    let (d, alpha_mode, stop) = (solver.spec.d, solver.spec.alpha, solver.spec.stop);
    let eng = global_engine();
    let (ws, observer) = (&mut solver.ws, &mut solver.observer);

    let mut xs: Vec<Mat> = Vec::with_capacity(b);
    for a in inputs {
        let mut x = ws.take(m, n);
        x.copy_from(a);
        x.scale(1.0 / a.fro_norm().max(1e-300));
        xs.push(x);
    }
    let mut rs: Vec<Mat> = Vec::with_capacity(b);
    for x in &xs {
        let mut r = ws.take(n, n);
        eng.syrk_at_a_into(&mut r, x);
        r.scale(-1.0);
        r.add_diag(1.0);
        rs.push(r);
    }
    let mut xn = ws.take(m, n); // shared spare, rotates through the members
    let mut g = ws.take(n, n);
    let mut r2 = if d == 2 { Some(ws.take(n, n)) } else { None };
    let mut fit = FitScratch::new(alpha_mode, d, n, ws);

    let sw = Stopwatch::start();
    let mut recs: Vec<RunRecorder<'_>> =
        rs.iter().map(|r| RunRecorder::start(r.fro_norm())).collect();
    let mut live = vec![true; b];
    for _ in 0..stop.max_iters {
        for j in 0..b {
            if live[j] && rs[j].fro_norm() < stop.tol {
                live[j] = false;
            }
        }
        if live.iter().all(|l| !l) {
            break;
        }
        fit.next_iteration(rng);
        for j in 0..b {
            if !live[j] {
                continue;
            }
            let alpha = fit.alpha(&rs[j], &eng, ws);
            if let Some(r2buf) = r2.as_mut() {
                eng.matmul_into(r2buf, &rs[j], &rs[j]);
            }
            update_poly_into(&mut g, &rs[j], r2.as_ref(), d, alpha, &eng, ws);
            eng.matmul_into(&mut xn, &xs[j], &g);
            std::mem::swap(&mut xs[j], &mut xn);
            eng.syrk_at_a_into(&mut rs[j], &xs[j]);
            rs[j].scale(-1.0);
            rs[j].add_diag(1.0);
            let res = rs[j].fro_norm();
            if recs[j].step_guard(&stop, alpha, res) {
                live[j] = false;
            }
            notify(observer, j, &recs[j], alpha, res, sw.elapsed_s());
        }
    }

    let mut outs = Vec::with_capacity(b);
    for (x, rec) in xs.iter().zip(recs) {
        outs.push(MatFnOutput { primary: x.clone(), secondary: None, log: rec.finish(&stop) });
    }
    for x in xs {
        ws.put(x);
    }
    for r in rs {
        ws.put(r);
    }
    ws.put(xn);
    ws.put(g);
    if let Some(buf) = r2 {
        ws.put(buf);
    }
    fit.release(ws);
    outs
}

/// Lockstep sign batch: the batched form of `prism::sign::sign_prism_in`'s
/// loop (always normalised, as the solver path runs it).
fn sign_batch(solver: &mut Solver, inputs: &[&Mat], rng: &mut Rng) -> Vec<MatFnOutput> {
    let b = inputs.len();
    assert!(inputs[0].is_square(), "sign: square input required");
    let n = inputs[0].rows();
    let (d, alpha_mode, stop) = (solver.spec.d, solver.spec.alpha, solver.spec.stop);
    let eng = global_engine();
    let (ws, observer) = (&mut solver.ws, &mut solver.observer);

    let mut xs: Vec<Mat> = Vec::with_capacity(b);
    for a in inputs {
        let mut x = ws.take(n, n);
        x.copy_from(a);
        x.scale(1.0 / a.fro_norm().max(1e-300));
        xs.push(x);
    }
    let mut rs: Vec<Mat> = Vec::with_capacity(b);
    for x in &xs {
        let mut r = ws.take(n, n);
        eng.matmul_into(&mut r, x, x);
        r.scale(-1.0);
        r.add_diag(1.0);
        r.symmetrize();
        rs.push(r);
    }
    let mut xn = ws.take(n, n);
    let mut g = ws.take(n, n);
    let mut r2 = if d == 2 { Some(ws.take(n, n)) } else { None };
    let mut fit = FitScratch::new(alpha_mode, d, n, ws);

    let sw = Stopwatch::start();
    let mut recs: Vec<RunRecorder<'_>> =
        rs.iter().map(|r| RunRecorder::start(r.fro_norm())).collect();
    let mut live = vec![true; b];
    for _ in 0..stop.max_iters {
        for j in 0..b {
            if live[j] && rs[j].fro_norm() < stop.tol {
                live[j] = false;
            }
        }
        if live.iter().all(|l| !l) {
            break;
        }
        fit.next_iteration(rng);
        for j in 0..b {
            if !live[j] {
                continue;
            }
            let alpha = fit.alpha(&rs[j], &eng, ws);
            if let Some(r2buf) = r2.as_mut() {
                eng.matmul_into(r2buf, &rs[j], &rs[j]);
            }
            update_poly_into(&mut g, &rs[j], r2.as_ref(), d, alpha, &eng, ws);
            eng.matmul_into(&mut xn, &xs[j], &g);
            std::mem::swap(&mut xs[j], &mut xn);
            eng.matmul_into(&mut rs[j], &xs[j], &xs[j]);
            rs[j].scale(-1.0);
            rs[j].add_diag(1.0);
            rs[j].symmetrize();
            let res = rs[j].fro_norm();
            if recs[j].step_guard(&stop, alpha, res) {
                live[j] = false;
            }
            notify(observer, j, &recs[j], alpha, res, sw.elapsed_s());
        }
    }

    let mut outs = Vec::with_capacity(b);
    for (x, rec) in xs.iter().zip(recs) {
        outs.push(MatFnOutput { primary: x.clone(), secondary: None, log: rec.finish(&stop) });
    }
    for x in xs {
        ws.put(x);
    }
    for r in rs {
        ws.put(r);
    }
    ws.put(xn);
    ws.put(g);
    if let Some(buf) = r2 {
        ws.put(buf);
    }
    fit.release(ws);
    outs
}

/// Lockstep coupled square-root batch: the batched form of
/// `prism::sqrt::sqrt_prism_in`'s loop. Serves both [`MatFnTask::Sqrt`] and
/// [`MatFnTask::InvSqrt`] (primary/secondary swap, like the solver).
fn sqrt_batch(solver: &mut Solver, inputs: &[&Mat], rng: &mut Rng) -> Vec<MatFnOutput> {
    let b = inputs.len();
    assert!(inputs[0].is_square(), "sqrt: square input required");
    let n = inputs[0].rows();
    let (d, alpha_mode, stop) = (solver.spec.d, solver.spec.alpha, solver.spec.stop);
    let want_sqrt = solver.task == MatFnTask::Sqrt;
    let eng = global_engine();
    let (ws, observer) = (&mut solver.ws, &mut solver.observer);

    let cs: Vec<f64> = inputs.iter().map(|a| a.fro_norm().max(1e-300)).collect();
    let mut xs: Vec<Mat> = Vec::with_capacity(b);
    let mut ys: Vec<Mat> = Vec::with_capacity(b);
    for (a, &c) in inputs.iter().zip(&cs) {
        let mut x = ws.take(n, n);
        x.copy_from(a);
        x.scale(1.0 / c);
        xs.push(x);
        let mut y = ws.take(n, n);
        y.fill_with(0.0);
        y.add_diag(1.0);
        ys.push(y);
    }
    // Y-first residual pairing, as in the sequential engine (Higham 1997's
    // numerically stable form).
    let mut rs: Vec<Mat> = Vec::with_capacity(b);
    for (x, y) in xs.iter().zip(&ys) {
        let mut r = ws.take(n, n);
        eng.matmul_into(&mut r, y, x);
        r.scale(-1.0);
        r.add_diag(1.0);
        r.symmetrize();
        rs.push(r);
    }
    let mut xn = ws.take(n, n);
    let mut yn = ws.take(n, n);
    let mut g = ws.take(n, n);
    let mut r2 = if d == 2 { Some(ws.take(n, n)) } else { None };
    let mut fit = FitScratch::new(alpha_mode, d, n, ws);

    let sw = Stopwatch::start();
    let mut recs: Vec<RunRecorder<'_>> =
        rs.iter().map(|r| RunRecorder::start(r.fro_norm())).collect();
    let mut live = vec![true; b];
    for _ in 0..stop.max_iters {
        for j in 0..b {
            if live[j] && rs[j].fro_norm() < stop.tol {
                live[j] = false;
            }
        }
        if live.iter().all(|l| !l) {
            break;
        }
        fit.next_iteration(rng);
        for j in 0..b {
            if !live[j] {
                continue;
            }
            let alpha = fit.alpha(&rs[j], &eng, ws);
            if let Some(r2buf) = r2.as_mut() {
                eng.matmul_into(r2buf, &rs[j], &rs[j]);
            }
            update_poly_into(&mut g, &rs[j], r2.as_ref(), d, alpha, &eng, ws);
            eng.matmul_into(&mut xn, &xs[j], &g);
            std::mem::swap(&mut xs[j], &mut xn);
            eng.matmul_into(&mut yn, &g, &ys[j]);
            std::mem::swap(&mut ys[j], &mut yn);
            eng.matmul_into(&mut rs[j], &ys[j], &xs[j]);
            rs[j].scale(-1.0);
            rs[j].add_diag(1.0);
            rs[j].symmetrize();
            let res = rs[j].fro_norm();
            if recs[j].step_guard(&stop, alpha, res) {
                live[j] = false;
            }
            notify(observer, j, &recs[j], alpha, res, sw.elapsed_s());
        }
    }

    let mut outs = Vec::with_capacity(b);
    for (j, rec) in recs.into_iter().enumerate() {
        let sc = cs[j].sqrt();
        let sqrt = xs[j].scaled(sc);
        let inv_sqrt = ys[j].scaled(1.0 / sc);
        let (primary, secondary) = if want_sqrt {
            (sqrt, Some(inv_sqrt))
        } else {
            (inv_sqrt, Some(sqrt))
        };
        outs.push(MatFnOutput { primary, secondary, log: rec.finish(&stop) });
    }
    for x in xs {
        ws.put(x);
    }
    for y in ys {
        ws.put(y);
    }
    for r in rs {
        ws.put(r);
    }
    ws.put(xn);
    ws.put(yn);
    ws.put(g);
    if let Some(buf) = r2 {
        ws.put(buf);
    }
    fit.release(ws);
    outs
}
