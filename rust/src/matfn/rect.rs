//! Rectangular & low-rank orthogonalization: the [`MatFnTask::RectPolar`]
//! routes (see the `matfn` module header for the accuracy contract).
//!
//! Every solver in the crate can orthogonalize a rectangular `A` directly —
//! but the direct Newton–Schulz iteration on an m×n operand pays
//! O(min(m,n)²·max(m,n)) *per iteration*. Foundation-model layers are
//! rectangular (d_out × d_in, often 4× aspect), and "Low-rank
//! Orthogonalization for Large-scale Matrix Optimization" observes that the
//! polar factor factors through the small Gram matrix: for tall `A = UΣVᵀ`
//! (m ≥ n), `G = AᵀA = VΣ²Vᵀ`, so `A·G^{-1/2} = UVᵀ` — one p×p inverse-root
//! solve (p = min(m, n)) plus a single skinny GEMM replaces the whole
//! rectangular iteration. [`RectStrategy`] picks between three routes:
//!
//! * **Gram** — `G = AᵀA` (or `AAᵀ`, whichever is smaller) via SYRK, the
//!   existing coupled PRISM sqrt/inv-sqrt engine on the p×p Gram matrix
//!   (mixed precision supported), then one skinny GEMM. The per-iteration
//!   cost drops from O(p²·max(m,n)) to O(p³); forming G and applying
//!   `G^{-1/2}` are one-off O(p²·max(m,n)) terms. Note κ(G) = κ(A)², so the
//!   route wants a not-too-ill-conditioned (and full-rank) input — exactly
//!   the optimizer-gradient regime.
//! * **RangeFinder** — for genuinely low-rank updates: sketch `Y = A·Ωᵀ`
//!   with a Gaussian test matrix, orthonormalize `Y`, project to the small
//!   core `C = Q₁ᵀA`, polar-solve the core and expand back
//!   ([`crate::prism::lowrank`]).
//! * **Direct** — the ordinary rectangular Newton–Schulz iteration, the
//!   right call for near-square shapes where the Gram detour buys nothing.
//!
//! `Auto` routes by aspect ratio: Gram when `max(m,n) ≥ 2·min(m,n)`, Direct
//! otherwise (the flop crossover sits near aspect 2 — see the `perf_rect`
//! bench). `Auto` never picks `RangeFinder`: rank is a caller-known
//! property, not a shape-visible one.

use crate::linalg::gemm::{global_engine, Workspace};
use crate::linalg::Mat;
use crate::prism::driver::{AlphaMode, EngineHooks, StopRule};
use crate::prism::lowrank::{range_polar_in, RangeOpts};
use crate::prism::mixed::{polar_mixed_in, sqrt_mixed_in};
use crate::prism::polar::{polar_prism_in, PolarOpts, PolarResult};
use crate::prism::sqrt::{sqrt_prism_in, SqrtOpts};
use crate::rng::Rng;

/// Route selection for [`MatFnTask::RectPolar`] solves (module docs above).
///
/// [`MatFnTask::RectPolar`]: super::MatFnTask::RectPolar
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RectStrategy {
    /// Aspect-ratio heuristic: Gram at aspect ≥ 2, Direct otherwise.
    Auto,
    /// Always the Gram route (p×p inverse root + one skinny GEMM).
    Gram,
    /// Randomized range-finder with a rank-`rank` Gaussian sketch; exact
    /// when `rank ≥ rank(A)`, a range-restricted partial isometry otherwise.
    RangeFinder { rank: usize },
    /// Always the direct rectangular Newton–Schulz iteration.
    Direct,
}

impl RectStrategy {
    /// Canonical config/CLI token (`"auto"`, `"gram"`, `"range16"`,
    /// `"direct"`).
    pub fn name(&self) -> String {
        match self {
            RectStrategy::Auto => "auto".into(),
            RectStrategy::Gram => "gram".into(),
            RectStrategy::RangeFinder { rank } => format!("range{rank}"),
            RectStrategy::Direct => "direct".into(),
        }
    }

    /// Parse a config/CLI token (`"auto"` | `"gram"` | `"direct"` |
    /// `"range<K>"` with K ≥ 1).
    pub fn parse(s: &str) -> Option<RectStrategy> {
        match s {
            "auto" => Some(RectStrategy::Auto),
            "gram" => Some(RectStrategy::Gram),
            "direct" => Some(RectStrategy::Direct),
            t if t.starts_with("range") => t["range".len()..]
                .parse::<usize>()
                .ok()
                .filter(|&k| k >= 1)
                .map(|rank| RectStrategy::RangeFinder { rank }),
            _ => None,
        }
    }
}

/// Options for a RectPolar run; `mixed` mirrors the solver's
/// [`super::Precision`] decision (d ≤ 2 only — the caller gates that).
pub(crate) struct RectPolarOpts {
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
    pub strategy: RectStrategy,
    pub mixed: bool,
}

/// Resolve `Auto` against the shape; the returned strategy is never `Auto`.
pub(crate) fn resolve_route(strategy: RectStrategy, m: usize, n: usize) -> RectStrategy {
    match strategy {
        RectStrategy::Auto => {
            if m.max(n) >= 2 * m.min(n).max(1) {
                RectStrategy::Gram
            } else {
                RectStrategy::Direct
            }
        }
        s => s,
    }
}

/// Workspace-pooled RectPolar core: route per [`resolve_route`], then
/// delegate. `hooks.x0` only reaches the Direct route (the Gram core is a
/// coupled sqrt, which cannot warm-start from a polar factor, and the
/// range-finder core lives in a different space).
pub(crate) fn rect_polar_in(
    a: &Mat,
    opts: &RectPolarOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> PolarResult {
    let (m, n) = a.shape();
    match resolve_route(opts.strategy, m, n) {
        RectStrategy::Direct => {
            let popts = PolarOpts { d: opts.d, alpha: opts.alpha, stop: opts.stop };
            if opts.mixed {
                polar_mixed_in(a, &popts, rng, ws, hooks)
            } else {
                polar_prism_in(a, &popts, rng, ws, hooks)
            }
        }
        RectStrategy::RangeFinder { rank } => {
            let ropts = RangeOpts { d: opts.d, alpha: opts.alpha, stop: opts.stop, rank };
            range_polar_in(a, &ropts, rng, ws, hooks)
        }
        RectStrategy::Gram | RectStrategy::Auto => gram_polar_in(a, opts, rng, ws, hooks),
    }
}

/// The Gram route: `G = AᵀA` (tall) or `AAᵀ` (wide) via SYRK, coupled
/// sqrt/inv-sqrt on the p×p `G`, then `Q = A·G^{-1/2}` (tall) or
/// `G^{-1/2}·A` (wide). The returned log is the Gram-core solve's log — its
/// residuals are `‖I − Y X‖_F` on the normalized `G`, so `converged` means
/// the inverse root (and hence `Q`) met the stop rule.
fn gram_polar_in(
    a: &Mat,
    opts: &RectPolarOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> PolarResult {
    let (m, n) = a.shape();
    let eng = global_engine();
    let tall = m >= n;
    let mut g = ws.take(m.min(n), m.min(n));
    if tall {
        eng.syrk_at_a_into(&mut g, a);
    } else {
        eng.syrk_a_at_into(&mut g, a);
    }
    let sopts = SqrtOpts { d: opts.d, alpha: opts.alpha, stop: opts.stop };
    // Drop x0 (the coupled core cannot use it); the `match` re-coerces the
    // observer's trait-object lifetime, as in the engines' own recursions.
    let EngineHooks { x0: _, observer, event_base, job } = hooks;
    let core_hooks = EngineHooks {
        x0: None,
        observer: match observer {
            Some(o) => Some(o),
            None => None,
        },
        event_base,
        job,
    };
    let sr = if opts.mixed {
        sqrt_mixed_in(&g, &sopts, rng, ws, core_hooks)
    } else {
        sqrt_prism_in(&g, &sopts, rng, ws, core_hooks)
    };
    let mut q = ws.take(m, n);
    if tall {
        eng.matmul_into(&mut q, a, &sr.inv_sqrt);
    } else {
        eng.matmul_into(&mut q, &sr.inv_sqrt, a);
    }
    let out = PolarResult { q: q.clone(), log: sr.log, transposed: false };
    ws.put(g);
    ws.put(q);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;
    use crate::prism::polar::orthogonality_error;
    use crate::randmat;

    fn exact_polar(a: &Mat) -> Mat {
        let (m, n) = a.shape();
        if m >= n {
            svd(a).polar_factor()
        } else {
            svd(&a.transpose()).polar_factor().transpose()
        }
    }

    fn opts(strategy: RectStrategy, mixed: bool) -> RectPolarOpts {
        RectPolarOpts {
            d: 2,
            alpha: AlphaMode::Sketched { p: 8 },
            stop: StopRule::default().with_max_iters(200).with_tol(1e-12),
            strategy,
            mixed,
        }
    }

    #[test]
    fn auto_routes_by_aspect() {
        assert_eq!(resolve_route(RectStrategy::Auto, 64, 32), RectStrategy::Gram);
        assert_eq!(resolve_route(RectStrategy::Auto, 32, 64), RectStrategy::Gram);
        assert_eq!(resolve_route(RectStrategy::Auto, 48, 32), RectStrategy::Direct);
        assert_eq!(resolve_route(RectStrategy::Auto, 32, 32), RectStrategy::Direct);
        let forced = RectStrategy::RangeFinder { rank: 4 };
        assert_eq!(resolve_route(forced, 64, 8), forced);
    }

    #[test]
    fn strategy_tokens_round_trip() {
        for s in [
            RectStrategy::Auto,
            RectStrategy::Gram,
            RectStrategy::Direct,
            RectStrategy::RangeFinder { rank: 16 },
        ] {
            assert_eq!(RectStrategy::parse(&s.name()), Some(s), "{}", s.name());
        }
        assert_eq!(RectStrategy::parse("range0"), None);
        assert_eq!(RectStrategy::parse("florb"), None);
    }

    #[test]
    fn gram_route_matches_svd_polar_both_orientations() {
        let mut rng = Rng::seed_from(1);
        let s = randmat::logspace(0.1, 1.0, 12);
        let tall = randmat::with_spectrum(&mut rng, 48, 12, &s);
        let wide = tall.transpose();
        for a in [&tall, &wide] {
            let mut ws = Workspace::new();
            let out =
                rect_polar_in(a, &opts(RectStrategy::Gram, false), &mut rng, &mut ws, EngineHooks::none());
            assert!(out.log.converged, "res={}", out.log.final_residual());
            assert_eq!(out.q.shape(), a.shape());
            let err = out.q.sub(&exact_polar(a)).max_abs();
            assert!(err < 1e-9, "{:?}: gram polar err {err}", a.shape());
            assert!(orthogonality_error(&out.q) < 1e-8);
        }
    }

    #[test]
    fn gram_route_warm_calls_are_allocation_free() {
        let mut rng = Rng::seed_from(2);
        let s = randmat::logspace(0.1, 1.0, 10);
        let a = randmat::with_spectrum(&mut rng, 40, 10, &s);
        let mut ws = Workspace::new();
        let o = opts(RectStrategy::Gram, false);
        let _ = rect_polar_in(&a, &o, &mut rng, &mut ws, EngineHooks::none());
        let allocs = ws.allocations();
        assert!(allocs > 0, "cold call populates the pool");
        for _ in 0..2 {
            let _ = rect_polar_in(&a, &o, &mut rng, &mut ws, EngineHooks::none());
        }
        assert_eq!(ws.allocations(), allocs, "warm gram solves must not miss the pool");
    }

    #[test]
    fn mixed_gram_route_matches_svd_at_mixed_tolerance() {
        let mut rng = Rng::seed_from(3);
        let s = randmat::logspace(0.1, 1.0, 10);
        let a = randmat::with_spectrum(&mut rng, 60, 10, &s);
        let mut ws = Workspace::new();
        let out =
            rect_polar_in(&a, &opts(RectStrategy::Gram, true), &mut rng, &mut ws, EngineHooks::none());
        let err = out.q.sub(&exact_polar(&a)).max_abs();
        assert!(err < 1e-4, "mixed gram polar err {err}");
    }

    #[test]
    fn direct_route_is_the_plain_polar_iteration() {
        // Same opts, same RNG stream ⇒ the Direct route must be bit-identical
        // to polar_prism_in: it *is* that call.
        let mut rng = Rng::seed_from(4);
        let a = randmat::gaussian(&mut rng, 20, 16);
        let o = opts(RectStrategy::Direct, false);
        let mut ws = Workspace::new();
        let via_rect =
            rect_polar_in(&a, &o, &mut Rng::seed_from(9), &mut ws, EngineHooks::none());
        let popts = PolarOpts { d: o.d, alpha: o.alpha, stop: o.stop };
        let direct = polar_prism_in(
            &a,
            &popts,
            &mut Rng::seed_from(9),
            &mut Workspace::new(),
            EngineHooks::none(),
        );
        assert_eq!(via_rect.q, direct.q);
    }
}
