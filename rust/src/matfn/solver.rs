//! The stateful [`Solver`]: plan once, execute many times.
//!
//! A `Solver` binds a ([`MatFnTask`], [`SolverSpec`]) pair to a persistent
//! [`Workspace`] and dispatches into the engine cores (`*_in` functions)
//! that draw every ping-pong buffer from that pool — the second same-shape
//! `solve` performs zero heap allocations in the iteration hot loop.

use super::rect::{rect_polar_in, RectPolarOpts};
use super::{BoxObserver, MatFnOutput, MatFnSolver, MatFnTask, Method, Precision, SolverSpec};
use crate::baselines::cans::{polar_cans_in, CansOpts};
use crate::baselines::eigen_fn;
use crate::baselines::polar_express::PolarExpress;
use crate::config::Backend;
use crate::linalg::gemm::Workspace;
use crate::linalg::Mat;
use crate::prism::chebyshev::{chebyshev_inverse_in, ChebyshevOpts};
use crate::prism::db_newton::{db_newton_prism_in, DbNewtonOpts};
use crate::prism::driver::{AlphaMode, EngineHooks, IterEvent, IterationLog, RunRecorder, StopRule};
use crate::prism::inverse_newton::{inv_root_prism_in, InvRootOpts};
use crate::prism::mixed::{polar_mixed_in, sqrt_mixed_in};
use crate::prism::polar::{polar_prism_in, PolarOpts};
use crate::prism::sign::{sign_prism_in, SignOpts};
use crate::prism::sqrt::{sqrt_prism_in, SqrtOpts};
use crate::rng::Rng;
use crate::util::{Error, Result};

/// A planned, reusable matrix-function solver. See the module docs of
/// [`crate::matfn`] for the quickstart.
pub struct Solver {
    pub(super) task: MatFnTask,
    pub(super) spec: SolverSpec,
    pub(super) ws: Workspace,
    pub(super) observer: Option<BoxObserver>,
    /// Remez schedule, built once when the method is PolarExpress.
    pe: Option<PolarExpress>,
}

/// Registry-style method token for a spec (the half before the task in a
/// name like `"prism5-polar"`). Kept in sync with `registry::parse_method`.
pub(super) fn method_token(spec: &SolverSpec) -> String {
    let classic = matches!(spec.alpha, AlphaMode::Classic);
    match spec.method {
        Method::NewtonSchulz => match spec.alpha {
            AlphaMode::Classic => "ns".into(),
            AlphaMode::Exact => "prism-exact".into(),
            AlphaMode::Fixed(_) => "ns-fixed".into(),
            AlphaMode::Sketched { .. } | AlphaMode::SketchedKind { .. } => {
                format!("prism{}", 2 * spec.d + 1)
            }
        },
        Method::InverseNewton => {
            if classic { "invnewton-classic".into() } else { "invnewton".into() }
        }
        Method::DbNewton => {
            if classic { "newton-classic".into() } else { "newton".into() }
        }
        Method::Chebyshev => {
            if classic { "cheb-classic".into() } else { "cheb".into() }
        }
        Method::PolarExpress => "pe".into(),
        Method::Cans => "cans".into(),
        Method::Eigen => "eigen".into(),
    }
}

/// Reject non-finite inputs before they enter any iteration. A NaN or ±∞
/// anywhere in `a` poisons every downstream GEMM, the sketched traces and
/// the residual in one step — the iteration would spin to `max_iters`
/// producing NaN "results" that only fail much later, far from the cause.
/// Shared by [`Solver::try_solve`] and the coordinator service's submit
/// path, so a poisoned matrix is refused at the boundary with a typed
/// [`Error::Numerical`] instead of corrupting a batch.
pub(crate) fn validate_input(a: &Mat) -> Result<()> {
    if a.has_non_finite() {
        return Err(crate::numerical_err!(
            "matfn: input {}x{} contains a non-finite entry (NaN or infinity)",
            a.rows(),
            a.cols()
        ));
    }
    Ok(())
}

fn validate(task: MatFnTask, spec: &SolverSpec) -> Result<()> {
    if let MatFnTask::InvRoot { p } = task {
        if p == 0 {
            return Err(Error::Parse("matfn: invroot needs p >= 1".into()));
        }
    }
    if spec.method == Method::NewtonSchulz && spec.d == 0 {
        return Err(Error::Parse("matfn: newton-schulz needs degree d >= 1".into()));
    }
    let ok = match spec.method {
        Method::NewtonSchulz => matches!(
            task,
            MatFnTask::Polar
                | MatFnTask::RectPolar
                | MatFnTask::Sign
                | MatFnTask::Sqrt
                | MatFnTask::InvSqrt
        ),
        Method::InverseNewton => matches!(
            task,
            MatFnTask::InvRoot { .. } | MatFnTask::InvSqrt | MatFnTask::Inverse
        ),
        Method::DbNewton => matches!(task, MatFnTask::Sqrt | MatFnTask::InvSqrt),
        Method::Chebyshev => matches!(task, MatFnTask::Inverse),
        Method::PolarExpress => {
            matches!(task, MatFnTask::Polar | MatFnTask::Sqrt | MatFnTask::InvSqrt)
        }
        Method::Cans => matches!(task, MatFnTask::Polar),
        Method::Eigen => true,
    };
    if ok {
        Ok(())
    } else {
        Err(Error::Parse(format!(
            "matfn: method {:?} cannot compute task '{}'",
            spec.method,
            task.name()
        )))
    }
}

/// Concatenate two runs of the same iteration (warm-α phase + fitted phase).
/// The second run's initial residual equals the first run's final one (same
/// iterate, same residual formula), so the duplicate entry is dropped.
fn chain_logs(mut a: IterationLog, b: IterationLog) -> IterationLog {
    let base_t = a.wall_s;
    a.alphas.extend(b.alphas);
    a.residuals.extend(b.residuals.into_iter().skip(1));
    a.times_s.extend(b.times_s.iter().map(|t| t + base_t));
    a.gemm_calls += b.gemm_calls;
    a.wall_s += b.wall_s;
    a.converged = b.converged;
    a.diverged = b.diverged;
    a
}

/// Re-borrow the solver's boxed observer as the engine-facing hook type.
/// (The `match` is a coercion site: it drops the box's `Send` bound and
/// shortens the trait-object lifetime, which `Option::map` cannot.)
/// `job` stamps every streamed event with a batch-member index (0 for plain
/// solves — see [`IterEvent::job`]).
fn hooks<'a>(
    observer: &'a mut Option<BoxObserver>,
    x0: Option<&'a Mat>,
    job: usize,
) -> EngineHooks<'a> {
    hooks_based(observer, x0, (0, 0.0), job)
}

/// Like [`hooks`], with an event offset for chained engine calls (warm-α
/// phase 2), keeping streamed iteration indices and times continuous with
/// the chained log.
fn hooks_based<'a>(
    observer: &'a mut Option<BoxObserver>,
    x0: Option<&'a Mat>,
    event_base: (usize, f64),
    job: usize,
) -> EngineHooks<'a> {
    let observer: Option<&'a mut dyn FnMut(&IterEvent)> = match observer.as_mut() {
        Some(b) => Some(&mut **b),
        None => None,
    };
    EngineHooks { x0, observer, event_base, job }
}

impl Solver {
    /// Plan a solver; rejects (task, method) pairs the method cannot serve,
    /// naming both halves in the error.
    pub fn new(task: MatFnTask, spec: SolverSpec) -> Result<Solver> {
        validate(task, &spec)?;
        let pe = if spec.method == Method::PolarExpress {
            Some(PolarExpress::paper_default())
        } else {
            None
        };
        Ok(Solver { task, spec, ws: Workspace::new(), observer: None, pe })
    }

    /// Plan a solver for an optimizer/service [`Backend`] selection with an
    /// iteration budget — the dispatch previously hand-rolled by every
    /// consumer. `PrismNewton` has no polar form, so for [`MatFnTask::Polar`]
    /// it stands in with PRISM-5 (the same orthogonalization role), exactly
    /// as the old `PolarBackend` did.
    pub fn for_backend(backend: Backend, task: MatFnTask, iters: usize) -> Result<Solver> {
        Self::for_backend_tuned(backend, task, iters, None, None)
    }

    /// [`Solver::for_backend`] with the service's tuning knobs threaded
    /// through: `tol` overrides the per-task default stopping tolerance and
    /// `sketch_p` the sketch size of sketched α specs (it is ignored by
    /// classic/exact/direct backends, which draw no sketches). This is the
    /// constructor the coordinator service uses so `service.tol` /
    /// `service.sketch_p` in TOML actually reach the solvers.
    pub fn for_backend_tuned(
        backend: Backend,
        task: MatFnTask,
        iters: usize,
        tol: Option<f64>,
        sketch_p: Option<usize>,
    ) -> Result<Solver> {
        let tol = tol.unwrap_or(match task {
            MatFnTask::Polar | MatFnTask::RectPolar | MatFnTask::Sign => 1e-7,
            _ => 1e-9,
        });
        let stop = StopRule::default().with_max_iters(iters).with_tol(tol);
        // PolarExpress's Remez schedule is a square-polar specialist; for
        // RectPolar it stands in with PRISM-5 (the rect routes' Gram/range
        // cores are NS-family anyway), mirroring the PrismNewton fallback.
        let spec = match backend {
            Backend::NewtonSchulz => SolverSpec::ns_classic(2),
            Backend::PolarExpress => {
                if task == MatFnTask::RectPolar {
                    SolverSpec::prism(2)
                } else {
                    SolverSpec::polar_express()
                }
            }
            Backend::Prism3 => SolverSpec::prism(1),
            Backend::Prism5 => SolverSpec::prism(2),
            Backend::Eigen => SolverSpec::eigen(),
            Backend::PrismNewton => {
                if matches!(task, MatFnTask::Polar | MatFnTask::RectPolar) {
                    SolverSpec::prism(2)
                } else {
                    SolverSpec::db_newton(true)
                }
            }
        }
        .with_stop(stop);
        let spec = match (sketch_p, spec.alpha) {
            (Some(p), AlphaMode::Sketched { .. }) => {
                spec.with_alpha(AlphaMode::Sketched { p })
            }
            (Some(p), AlphaMode::SketchedKind { kind, .. }) => {
                spec.with_alpha(AlphaMode::SketchedKind { p, kind })
            }
            _ => spec,
        };
        Solver::new(task, spec)
    }

    pub fn task(&self) -> MatFnTask {
        self.task
    }

    /// Registry-style name; `registry::resolve(name)` round-trips for every
    /// registered configuration.
    pub fn name(&self) -> String {
        format!("{}-{}", method_token(&self.spec), self.task.name())
    }

    pub fn spec(&self) -> &SolverSpec {
        &self.spec
    }

    /// Mutable spec access for in-place re-planning (stop rule, α mode,
    /// warm-iters). The workspace is kept — same-shape buffers stay warm.
    pub fn spec_mut(&mut self) -> &mut SolverSpec {
        &mut self.spec
    }

    /// Replace the stopping rule (builder-style convenience).
    pub fn set_stop(&mut self, stop: StopRule) {
        self.spec.stop = stop;
    }

    /// Workspace misses so far (see [`Workspace::allocations`]). Flat across
    /// two same-shape solves ⇔ the second ran allocation-free.
    pub fn workspace_allocations(&self) -> usize {
        self.ws.allocations()
    }

    /// Install or remove the per-iteration observer.
    pub fn set_observer(&mut self, observer: Option<BoxObserver>) {
        self.observer = observer;
    }

    /// Compute the matrix function of `a` (see [`MatFnSolver::solve`]).
    pub fn solve(&mut self, a: &Mat, rng: &mut Rng) -> MatFnOutput {
        self.run(a, None, rng, 0)
    }

    /// [`Solver::solve`] with boundary validation: rejects inputs holding
    /// NaN/±∞ entries with a typed [`Error::Numerical`] *before* any
    /// iteration runs (and before any RNG is consumed), instead of
    /// spinning `max_iters` on poisoned arithmetic.
    pub fn try_solve(&mut self, a: &Mat, rng: &mut Rng) -> Result<MatFnOutput> {
        validate_input(a)?;
        Ok(self.solve(a, rng))
    }

    /// [`Solver::try_solve`] that also converts an untrustworthy *outcome*
    /// — divergence or non-finite entries in the result (see
    /// [`MatFnOutput::is_failure`]) — into a typed [`Error::Numerical`], so
    /// retry policies can branch on `Result` instead of inspecting logs.
    pub fn solve_checked(&mut self, a: &Mat, rng: &mut Rng) -> Result<MatFnOutput> {
        let out = self.try_solve(a, rng)?;
        if out.is_failure() {
            return Err(Error::Numerical(format!(
                "{}: solve failed (diverged = {}, final residual = {:e})",
                self.name(),
                out.log.diverged,
                out.log.final_residual()
            )));
        }
        Ok(out)
    }

    /// Warm-start from `x0` (see [`MatFnSolver::solve_from`]).
    pub fn solve_from(&mut self, a: &Mat, x0: &Mat, rng: &mut Rng) -> MatFnOutput {
        self.run(a, Some(x0), rng, 0)
    }

    /// Solve a batch of same-shape inputs, amortising PRISM's fitting
    /// overhead: Newton–Schulz-family solves (without a warm-α phase) run in
    /// **lockstep**, sharing one sketch fill per iteration across the whole
    /// batch — the sketch `S` is drawn independently of each input, so
    /// sharing it is statistically free — with every per-job panel drawn
    /// from this solver's single [`Workspace`] (allocation-free from the
    /// second same-size batch onward). Other methods run the jobs back to
    /// back through the same workspace.
    ///
    /// **RNG contract:** each output is bit-identical to
    /// `self.solve(inputs[j], &mut r)` where `r` is a clone of `rng`'s state
    /// at entry — every batch member reads the *same* per-job stream, which
    /// is exactly what makes the per-iteration sketch shareable. `rng` is
    /// left advanced by the longest member's consumption; batched and
    /// sequential execution are therefore interchangeable without changing
    /// results, and the conformance suites pin this.
    ///
    /// Per-job `IterationLog`s carry exact residual/α trajectories, but
    /// `wall_s`/`times_s`/`gemm_calls` of lockstep members span the shared
    /// batch execution (each job's recorder is live while its batch peers
    /// iterate on the same thread).
    pub fn solve_batch(&mut self, inputs: &[&Mat], rng: &mut Rng) -> Vec<MatFnOutput> {
        if inputs.is_empty() {
            return Vec::new();
        }
        let shape = inputs[0].shape();
        let uniform = inputs.iter().all(|a| a.shape() == shape);
        // RectPolar batches may legitimately mix shapes (one job per layer);
        // they always take the sequential path below. Every other task keeps
        // the hard same-shape contract.
        assert!(
            uniform || self.task == MatFnTask::RectPolar,
            "solve_batch: all inputs must share one shape"
        );
        // Mixed-precision solves take the sequential fallback: the lockstep
        // driver is an f64 engine, and the per-job stream contract already
        // makes sequential execution observationally identical. RectPolar
        // does too: its routes are chosen per shape and solved through the
        // Gram/range cores, which the lockstep driver does not model.
        if uniform
            && self.task != MatFnTask::RectPolar
            && self.spec.method == Method::NewtonSchulz
            && self.spec.warm_iters == 0
            && self.spec.precision == Precision::F64
            && inputs.len() > 1
        {
            return super::batch::ns_solve_batch(self, inputs, rng);
        }
        // Sequential fallback under the same per-job stream contract: every
        // job sees a clone of the entry RNG state (a no-op for the methods
        // that draw no randomness). The final `rng` state matches lockstep:
        // advanced by the longest member's consumption.
        let entry = rng.clone();
        let mut consumed = entry.clone();
        let mut most_iters = 0usize;
        let outs: Vec<MatFnOutput> = inputs
            .iter()
            .enumerate()
            .map(|(j, a)| {
                let mut r = entry.clone();
                let out = self.run(a, None, &mut r, j);
                if out.log.iters() >= most_iters {
                    most_iters = out.log.iters();
                    consumed = r;
                }
                out
            })
            .collect();
        *rng = consumed;
        outs
    }

    fn run(&mut self, a: &Mat, x0: Option<&Mat>, rng: &mut Rng, job: usize) -> MatFnOutput {
        let spec = self.spec;
        match spec.method {
            Method::NewtonSchulz => self.run_ns(a, x0, rng, job),
            Method::InverseNewton => {
                let p = match self.task {
                    MatFnTask::InvRoot { p } => p,
                    MatFnTask::InvSqrt => 2,
                    MatFnTask::Inverse => 1,
                    _ => unreachable!("validated"),
                };
                let opts = InvRootOpts { p, alpha: spec.alpha, stop: spec.stop };
                let out = inv_root_prism_in(
                    a,
                    &opts,
                    rng,
                    &mut self.ws,
                    hooks(&mut self.observer, x0, job),
                );
                MatFnOutput { primary: out.inv_root, secondary: None, log: out.log }
            }
            Method::DbNewton => {
                let opts = DbNewtonOpts { alpha: spec.alpha, stop: spec.stop };
                let out = db_newton_prism_in(
                    a,
                    &opts,
                    rng,
                    &mut self.ws,
                    hooks(&mut self.observer, None, job),
                );
                let (primary, secondary) = if self.task == MatFnTask::Sqrt {
                    (out.sqrt, Some(out.inv_sqrt))
                } else {
                    (out.inv_sqrt, Some(out.sqrt))
                };
                MatFnOutput { primary, secondary, log: out.log }
            }
            Method::Chebyshev => {
                let opts = ChebyshevOpts { alpha: spec.alpha, stop: spec.stop };
                let out = chebyshev_inverse_in(
                    a,
                    &opts,
                    rng,
                    &mut self.ws,
                    hooks(&mut self.observer, x0, job),
                );
                MatFnOutput { primary: out.inverse, secondary: None, log: out.log }
            }
            Method::PolarExpress => {
                let pe = self.pe.as_ref().expect("pe schedule built in Solver::new");
                match self.task {
                    MatFnTask::Polar => {
                        let (q, log) = pe.polar_in(
                            a,
                            &spec.stop,
                            &mut self.ws,
                            hooks(&mut self.observer, x0, job),
                        );
                        MatFnOutput { primary: q, secondary: None, log }
                    }
                    _ => {
                        let (sq, isq, log) = pe.sqrt_coupled_in(
                            a,
                            &spec.stop,
                            &mut self.ws,
                            hooks(&mut self.observer, None, job),
                        );
                        let (primary, secondary) = if self.task == MatFnTask::Sqrt {
                            (sq, Some(isq))
                        } else {
                            (isq, Some(sq))
                        };
                        MatFnOutput { primary, secondary, log }
                    }
                }
            }
            Method::Cans => {
                let opts = CansOpts { stop: spec.stop, ..CansOpts::default() };
                let (q, log) =
                    polar_cans_in(a, &opts, rng, &mut self.ws, hooks(&mut self.observer, x0, job));
                MatFnOutput { primary: q, secondary: None, log }
            }
            Method::Eigen => {
                // Direct method: the log records wall time and GEMM count of
                // the decomposition, with a zero "residual".
                let rec = RunRecorder::start(0.0);
                let (primary, secondary) = match self.task {
                    MatFnTask::Sqrt => {
                        (eigen_fn::sqrt_eigen(a), Some(eigen_fn::inv_sqrt_eigen(a, 0.0)))
                    }
                    MatFnTask::InvSqrt => {
                        (eigen_fn::inv_sqrt_eigen(a, 0.0), Some(eigen_fn::sqrt_eigen(a)))
                    }
                    MatFnTask::InvRoot { p } => {
                        (eigen_fn::inv_root_eigen(a, p, 0.0).expect("p >= 1 validated"), None)
                    }
                    MatFnTask::Polar | MatFnTask::RectPolar => (eigen_fn::polar_eigen(a), None),
                    MatFnTask::Sign => (eigen_fn::sign_eigen(a), None),
                    MatFnTask::Inverse => (eigen_fn::inverse_eigen(a), None),
                };
                MatFnOutput { primary, secondary, log: rec.finish(&spec.stop) }
            }
        }
    }

    /// Newton–Schulz dispatch, including the Muon warm-α phase (paper §C):
    /// pin α at the interval's upper bound for `warm_iters` iterations (no
    /// fit cost while the residual is still large), then continue with the
    /// fitted α from the warm iterate.
    fn run_ns(
        &mut self,
        a: &Mat,
        x0: Option<&Mat>,
        rng: &mut Rng,
        job: usize,
    ) -> MatFnOutput {
        let spec = self.spec;
        let warm_capable = matches!(self.task, MatFnTask::Polar | MatFnTask::Sign);
        let sketched = matches!(
            spec.alpha,
            AlphaMode::Sketched { .. } | AlphaMode::SketchedKind { .. }
        );
        if warm_capable && sketched && spec.warm_iters > 0 {
            let (_, hi) = crate::coeffs::alpha_interval(spec.d);
            if spec.warm_iters >= spec.stop.max_iters {
                return self.run_ns_once(a, x0, AlphaMode::Fixed(hi), spec.stop, rng, job);
            }
            let warm_stop = StopRule { max_iters: spec.warm_iters, ..spec.stop };
            let warm = self.run_ns_once(a, x0, AlphaMode::Fixed(hi), warm_stop, rng, job);
            let rest =
                StopRule { max_iters: spec.stop.max_iters - spec.warm_iters, ..spec.stop };
            let warm_iterate = warm.primary;
            // Phase 2 streams observer events offset by phase 1's iteration
            // count and wall time, so the trajectory stays continuous.
            let base = (warm.log.iters(), warm.log.wall_s);
            let fine =
                self.run_ns_chained(a, Some(&warm_iterate), spec.alpha, rest, base, rng, job);
            return MatFnOutput {
                log: chain_logs(warm.log, fine.log),
                primary: fine.primary,
                secondary: fine.secondary,
            };
        }
        self.run_ns_once(a, x0, spec.alpha, spec.stop, rng, job)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_ns_once(
        &mut self,
        a: &Mat,
        x0: Option<&Mat>,
        alpha: AlphaMode,
        stop: StopRule,
        rng: &mut Rng,
        job: usize,
    ) -> MatFnOutput {
        self.run_ns_chained(a, x0, alpha, stop, (0, 0.0), rng, job)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_ns_chained(
        &mut self,
        a: &Mat,
        x0: Option<&Mat>,
        alpha: AlphaMode,
        stop: StopRule,
        base: (usize, f64),
        rng: &mut Rng,
        job: usize,
    ) -> MatFnOutput {
        let d = self.spec.d;
        // The mixed drivers assemble the degree-1/2 update polynomial inline
        // in f32; higher degrees (Paterson–Stockmeyer) and sign(A) stay on
        // the f64 engines regardless of the spec — see [`Precision`].
        let mixed = self.spec.precision == Precision::Mixed && d <= 2;
        match self.task {
            MatFnTask::Polar => {
                let opts = PolarOpts { d, alpha, stop };
                let h = hooks_based(&mut self.observer, x0, base, job);
                let out = if mixed {
                    polar_mixed_in(a, &opts, rng, &mut self.ws, h)
                } else {
                    polar_prism_in(a, &opts, rng, &mut self.ws, h)
                };
                MatFnOutput { primary: out.q, secondary: None, log: out.log }
            }
            MatFnTask::RectPolar => {
                let opts = RectPolarOpts { d, alpha, stop, strategy: self.spec.rect, mixed };
                let h = hooks_based(&mut self.observer, x0, base, job);
                let out = rect_polar_in(a, &opts, rng, &mut self.ws, h);
                MatFnOutput { primary: out.q, secondary: None, log: out.log }
            }
            MatFnTask::Sign => {
                let opts = SignOpts { d, alpha, stop, normalize: true };
                let out = sign_prism_in(
                    a,
                    &opts,
                    rng,
                    &mut self.ws,
                    hooks_based(&mut self.observer, x0, base, job),
                );
                MatFnOutput { primary: out.s, secondary: None, log: out.log }
            }
            MatFnTask::Sqrt | MatFnTask::InvSqrt => {
                let opts = SqrtOpts { d, alpha, stop };
                let h = hooks(&mut self.observer, None, job);
                let out = if mixed {
                    sqrt_mixed_in(a, &opts, rng, &mut self.ws, h)
                } else {
                    sqrt_prism_in(a, &opts, rng, &mut self.ws, h)
                };
                let (primary, secondary) = if self.task == MatFnTask::Sqrt {
                    (out.sqrt, Some(out.inv_sqrt))
                } else {
                    (out.inv_sqrt, Some(out.sqrt))
                };
                MatFnOutput { primary, secondary, log: out.log }
            }
            _ => unreachable!("validated"),
        }
    }
}

impl MatFnSolver for Solver {
    fn task(&self) -> MatFnTask {
        Solver::task(self)
    }
    fn name(&self) -> String {
        Solver::name(self)
    }
    fn solve(&mut self, a: &Mat, rng: &mut Rng) -> MatFnOutput {
        Solver::solve(self, a, rng)
    }
    fn solve_from(&mut self, a: &Mat, x0: &Mat, rng: &mut Rng) -> MatFnOutput {
        Solver::solve_from(self, a, x0, rng)
    }
    fn set_observer(&mut self, observer: Option<BoxObserver>) {
        Solver::set_observer(self, observer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;

    #[test]
    fn invalid_combo_rejected_with_both_halves_named() {
        let err = Solver::new(MatFnTask::Sign, SolverSpec::cans()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("Cans") && msg.contains("sign"), "{msg}");
        assert!(Solver::new(MatFnTask::InvRoot { p: 0 }, SolverSpec::eigen()).is_err());
        assert!(Solver::new(MatFnTask::Polar, SolverSpec::prism(0)).is_err());
    }

    #[test]
    fn solver_reuse_is_deterministic_and_allocation_free() {
        let mut rng = Rng::seed_from(1);
        let a = randmat::gaussian(&mut rng, 24, 12);
        // Classic α — no sketch draws, so repeat solves are bit-identical.
        let mut s = Solver::new(MatFnTask::Polar, SolverSpec::ns_classic(2)).unwrap();
        let first = s.solve(&a, &mut rng);
        let allocs = s.workspace_allocations();
        assert!(allocs > 0);
        for _ in 0..3 {
            let again = s.solve(&a, &mut rng);
            assert_eq!(again.primary, first.primary, "reused buffers changed the result");
        }
        assert_eq!(s.workspace_allocations(), allocs, "warm solves must not allocate");
    }

    #[test]
    fn warm_alpha_phase_matches_paper_muon_shape() {
        let mut rng = Rng::seed_from(2);
        let s_spec = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s_spec);
        let stop = StopRule::default().with_max_iters(5).with_tol(1e-9);
        let mut s = Solver::new(
            MatFnTask::Polar,
            SolverSpec::prism(1).with_stop(stop).with_warm_iters(3),
        )
        .unwrap();
        // Observer events must stay continuous across the two internal
        // phases: iteration indices 0..5, no restart at the fitted phase.
        let seen =
            crate::runtime::sync::Arc::new(crate::runtime::sync::Mutex::new(Vec::new()));
        let sink = crate::runtime::sync::Arc::clone(&seen);
        s.set_observer(Some(Box::new(move |ev| {
            crate::util::lock_or_recover(&sink).push((ev.iter, ev.elapsed_s));
        })));
        let out = s.solve(&a, &mut rng);
        s.set_observer(None);
        {
            let seen = crate::util::lock_or_recover(&seen);
            let iters: Vec<usize> = seen.iter().map(|&(k, _)| k).collect();
            assert_eq!(iters, vec![0, 1, 2, 3, 4], "chained phases must not restart");
            for w in seen.windows(2) {
                assert!(w[1].1 >= w[0].1, "elapsed_s must be monotone across phases");
            }
        }
        assert_eq!(out.log.iters(), 5, "warm (3) + fitted (2) iterations");
        let (_, hi) = crate::coeffs::alpha_interval(1);
        for &al in &out.log.alphas[..3] {
            assert_eq!(al, hi, "warm phase pins α at the upper bound");
        }
        assert_eq!(out.log.residuals.len(), out.log.iters() + 1);
        let q = &out.primary;
        let before = crate::prism::polar::orthogonality_error(&a.scaled(1.0 / a.fro_norm()));
        let after = crate::prism::polar::orthogonality_error(q);
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    fn secondary_output_is_the_coupled_partner() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-2, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        let stop = StopRule::default().with_max_iters(200);
        let mut s = Solver::new(MatFnTask::InvSqrt, SolverSpec::prism(2).with_stop(stop)).unwrap();
        let out = s.solve(&a, &mut rng);
        assert!(out.log.converged);
        let sqrt = out.secondary.expect("coupled sqrt");
        let prod = matmul(&sqrt, &out.primary);
        assert!(prod.sub(&Mat::eye(10)).max_abs() < 1e-6);
    }

    #[test]
    fn for_backend_covers_service_tasks() {
        for b in [
            Backend::NewtonSchulz,
            Backend::PolarExpress,
            Backend::Prism3,
            Backend::Prism5,
            Backend::Eigen,
            Backend::PrismNewton,
        ] {
            for task in [MatFnTask::Polar, MatFnTask::RectPolar, MatFnTask::InvSqrt] {
                let s = Solver::for_backend(b, task, 30).unwrap();
                assert_eq!(MatFnSolver::task(&s), task);
            }
        }
        // PrismNewton's polar fallback is PRISM-5, as documented — and so is
        // PolarExpress's rectpolar fallback.
        let s = Solver::for_backend(Backend::PrismNewton, MatFnTask::Polar, 10).unwrap();
        assert_eq!(s.name(), "prism5-polar");
        let s = Solver::for_backend(Backend::PolarExpress, MatFnTask::RectPolar, 10).unwrap();
        assert_eq!(s.name(), "prism5-rectpolar");
    }

    #[test]
    fn warm_iters_at_or_over_budget_runs_whole_solve_at_pinned_alpha() {
        // warm_iters >= max_iters: the warm phase *is* the whole run — the
        // solver must fall back to a single pinned-α pass, not chain an
        // empty fitted phase (0 remaining iterations would underflow the
        // phase-2 stop rule).
        let mut rng = Rng::seed_from(11);
        let a = randmat::gaussian(&mut rng, 20, 12);
        let stop = StopRule::default().with_max_iters(4).with_tol(1e-12);
        let (_, hi) = crate::coeffs::alpha_interval(2);
        for warm in [4usize, 9] {
            let mut s = Solver::new(
                MatFnTask::Polar,
                SolverSpec::prism(2).with_stop(stop).with_warm_iters(warm),
            )
            .unwrap();
            let out = s.solve(&a, &mut Rng::seed_from(5));
            assert!(out.log.iters() <= 4);
            for &al in &out.log.alphas {
                assert_eq!(al, hi, "whole run pins α at the upper bound");
            }
        }
    }

    #[test]
    fn solve_from_after_shape_change_resizes_cleanly() {
        // The workspace recycles best-fit buffers; a warm start at a new
        // shape must not reuse a stale-shaped panel.
        let mut rng = Rng::seed_from(12);
        let mut s = Solver::new(MatFnTask::Polar, SolverSpec::prism(2)).unwrap();
        let a1 = randmat::gaussian(&mut rng, 24, 12);
        let q1 = s.solve(&a1, &mut rng);
        assert!(q1.log.converged);
        let a2 = randmat::gaussian(&mut rng, 16, 8);
        let cold = s.solve(&a2, &mut rng);
        assert!(cold.log.converged);
        let warm = s.solve_from(&a2, &cold.primary, &mut rng);
        assert_eq!(warm.primary.shape(), (16, 8));
        assert!(warm.log.converged);
        assert!(
            warm.log.iters() <= cold.log.iters(),
            "warm start from the answer must not be slower than cold"
        );
        // And back to the first shape again: both directions of the resize.
        assert!(s.solve_from(&a1, &q1.primary, &mut rng).log.converged);
    }

    #[test]
    fn try_solve_rejects_non_finite_input_without_consuming_rng() {
        let mut rng = Rng::seed_from(13);
        let mut a = randmat::gaussian(&mut rng, 8, 8);
        let mut s = Solver::new(MatFnTask::Polar, SolverSpec::prism(2)).unwrap();
        for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            a[(3, 5)] = poison;
            let before = rng.clone();
            let err = s.try_solve(&a, &mut rng).unwrap_err();
            assert!(matches!(err, Error::Numerical(_)), "{err}");
            assert!(err.to_string().contains("non-finite"), "{err}");
            assert_eq!(
                rng.normal(),
                before.clone().normal(),
                "rejection must not consume the RNG stream"
            );
            rng = before;
        }
        a[(3, 5)] = 0.0;
        assert!(s.try_solve(&a, &mut rng).is_ok());
    }

    #[test]
    fn mixed_precision_solver_reuse_is_allocation_free() {
        use super::super::Precision;
        let mut rng = Rng::seed_from(14);
        let w = randmat::logspace(1e-2, 1.0, 12);
        let a = randmat::sym_with_spectrum(&mut rng, 12, &w);
        let stop = StopRule::default().with_max_iters(200);
        let mut s = Solver::new(
            MatFnTask::InvSqrt,
            SolverSpec::prism(2).with_stop(stop).with_precision(Precision::Mixed),
        )
        .unwrap();
        let first = s.solve(&a, &mut rng);
        assert!(first.log.converged, "res={}", first.log.final_residual());
        let allocs = s.workspace_allocations();
        assert!(allocs > 0);
        let again = s.solve(&a, &mut rng);
        assert!(again.log.converged);
        assert_eq!(s.workspace_allocations(), allocs, "warm mixed solves must not allocate");
        // The coupled outputs still invert each other at mixed accuracy.
        let prod = matmul(first.secondary.as_ref().unwrap(), &first.primary);
        assert!(prod.sub(&Mat::eye(12)).max_abs() < 1e-6);
    }

    #[test]
    fn trait_object_dispatch_works() {
        let mut rng = Rng::seed_from(4);
        let a = randmat::gaussian(&mut rng, 16, 8);
        let mut s: Box<dyn MatFnSolver> =
            Box::new(Solver::new(MatFnTask::Polar, SolverSpec::prism(2)).unwrap());
        let out = s.solve(&a, &mut rng);
        assert!(out.log.converged);
        assert!(matmul_at_b(&out.primary, &out.primary).sub(&Mat::eye(8)).max_abs() < 1e-6);
    }
}
