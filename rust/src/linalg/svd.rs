//! Singular value decomposition for rectangular matrices (m >= n), via the
//! symmetric eigendecomposition of AᵀA with a Gram-correction for small
//! singular values. Accurate enough to serve as the exact-polar baseline and
//! the test oracle for the Newton–Schulz orthogonalization engines.

use super::eigen::symmetric_eigen;
use super::gemm::{matmul, syrk_at_a};
use super::Mat;

/// Thin SVD: `A = U diag(s) Vᵀ`, `U: m x n`, `V: n x n`, s descending.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub v: Mat,
}

/// Compute the thin SVD of `A` (m >= n). For m < n, the caller should
/// transpose. Singular vectors for tiny singular values are completed by
/// Gram–Schmidt against the already-computed ones.
pub fn svd(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    assert!(m >= n, "svd: need m >= n, got {m}x{n}; transpose first");
    let ata = syrk_at_a(a);
    let e = symmetric_eigen(&ata);
    // Descending singular values.
    let mut s: Vec<f64> = Vec::with_capacity(n);
    let mut v = Mat::zeros(n, n);
    for i in 0..n {
        let src = n - 1 - i; // eigen gives ascending
        s.push(e.values[src].max(0.0).sqrt());
        for r in 0..n {
            v[(r, i)] = e.vectors[(r, src)];
        }
    }
    // U = A V diag(1/s); columns whose singular value is below the AᵀA
    // round-off floor (≈ √eps · s_max) carry no directional information and
    // are completed by Gram–Schmidt instead.
    let av = matmul(a, &v);
    let mut u = Mat::zeros(m, n);
    let tol = s.first().copied().unwrap_or(0.0) * 1e-7;
    for j in 0..n {
        if s[j] > tol {
            let inv = 1.0 / s[j];
            for i in 0..m {
                u[(i, j)] = av[(i, j)] * inv;
            }
        } else {
            // Complete with a vector orthogonal to previous columns.
            // Start from e_{j mod m}, Gram-Schmidt, normalise.
            let mut col = vec![0.0; m];
            col[j % m] = 1.0;
            for prev in 0..j {
                let mut dot = 0.0;
                for i in 0..m {
                    dot += col[i] * u[(i, prev)];
                }
                for i in 0..m {
                    col[i] -= dot * u[(i, prev)];
                }
            }
            let norm: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for i in 0..m {
                    u[(i, j)] = col[i] / norm;
                }
            }
        }
    }
    Svd { u, s, v }
}

impl Svd {
    /// Exact polar factor `U Vᵀ` (the orthogonalization target of Muon).
    pub fn polar_factor(&self) -> Mat {
        matmul(&self.u, &self.v.transpose())
    }

    /// Reconstruct `A`.
    pub fn reconstruct(&self) -> Mat {
        let n = self.s.len();
        let mut us = self.u.clone();
        for j in 0..n {
            for i in 0..us.rows() {
                us[(i, j)] *= self.s[j];
            }
        }
        matmul(&us, &self.v.transpose())
    }

    /// Condition number σ_max / σ_min.
    pub fn cond(&self) -> f64 {
        let smax = self.s.first().copied().unwrap_or(0.0);
        let smin = self.s.last().copied().unwrap_or(0.0);
        smax / smin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_at_b;
    use crate::rng::Rng;

    #[test]
    fn svd_reconstructs() {
        let mut rng = Rng::seed_from(1);
        for &(m, n) in &[(10, 10), (20, 8), (33, 17)] {
            let a = Mat::gaussian(&mut rng, m, n, 1.0);
            let d = svd(&a);
            assert!(d.reconstruct().sub(&a).max_abs() < 1e-8, "{m}x{n}");
        }
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 15, 9, 1.0);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn polar_factor_is_orthogonal() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 18, 7, 1.0);
        let q = svd(&a).polar_factor();
        let qtq = matmul_at_b(&q, &q);
        assert!(qtq.sub(&Mat::eye(7)).max_abs() < 1e-8);
    }

    #[test]
    fn known_singular_values() {
        // A = diag(3, 2, 1) embedded in 5x3.
        let mut a = Mat::zeros(5, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 2.0;
        a[(2, 2)] = 1.0;
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-10);
        assert!((d.s[1] - 2.0).abs() < 1e-10);
        assert!((d.s[2] - 1.0).abs() < 1e-10);
        assert!((d.cond() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rank_deficient_still_orthogonal_u() {
        let mut rng = Rng::seed_from(4);
        // rank-2 matrix in 8x4
        let b = Mat::gaussian(&mut rng, 8, 2, 1.0);
        let c = Mat::gaussian(&mut rng, 2, 4, 1.0);
        let a = matmul(&b, &c);
        let d = svd(&a);
        // Tiny singular values come from eigenvalues of AᵀA at ~1e-16·scale,
        // so after sqrt they sit near 1e-7 · s[0].
        assert!(d.s[2] < 1e-6 * d.s[0] && d.s[3] < 1e-6 * d.s[0], "{:?}", d.s);
        let utu = matmul_at_b(&d.u, &d.u);
        assert!(utu.sub(&Mat::eye(4)).max_abs() < 1e-6);
    }
}
