//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Jacobi is slower asymptotically than tridiagonal QR but it is simple,
//! extremely robust, and accurate to machine precision — exactly what the
//! *baseline* matrix-function implementations (`baselines::eigen_fn`) and the
//! test oracles need. The sizes in the paper's optimizer experiments
//! (preconditioners ≤ 2048, here ≤ 512) are comfortably in range.

use super::Mat;

/// Result of a symmetric eigendecomposition `A = V diag(w) Vᵀ`.
pub struct SymEigen {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Column i of `vectors` is the eigenvector for `values[i]`.
    pub vectors: Mat,
}

/// Cyclic Jacobi eigensolver for symmetric `A`.
///
/// Panics if `A` is not square; symmetry is enforced by averaging.
pub fn symmetric_eigen(a: &Mat) -> SymEigen {
    assert!(a.is_square(), "symmetric_eigen: matrix must be square");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle.
                let theta = 0.5 * (aqq - app) / apq;
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Apply rotation to rows/cols p, q of M.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for i in 0..n {
            vectors[(i, new_col)] = v[(i, old_col)];
        }
    }
    SymEigen { values, vectors }
}

impl SymEigen {
    /// Apply a scalar function to the spectrum: `f(A) = V diag(f(w)) Vᵀ`.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.values.len();
        let mut scaled = Mat::zeros(n, n);
        // scaled = V diag(f(w))
        for i in 0..n {
            for j in 0..n {
                scaled[(i, j)] = self.vectors[(i, j)] * f(self.values[j]);
            }
        }
        // result = scaled Vᵀ (direct triple loop keeps the GEMM counter for
        // the iterative algorithms honest — eigen baselines report their own
        // timing, not GEMM counts).
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for k in 0..n {
                let s = scaled[(i, k)];
                for j in 0..n {
                    out[(i, j)] += s * self.vectors[(j, k)];
                }
            }
        }
        out.symmetrize();
        out
    }

    /// Condition number (|λ|max / |λ|min).
    pub fn cond(&self) -> f64 {
        let mx = self.values.iter().fold(0.0_f64, |m, x| m.max(x.abs()));
        let mn = self.values.iter().fold(f64::INFINITY, |m, x| m.min(x.abs()));
        mx / mn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, syrk_at_a};
    use crate::rng::Rng;

    #[test]
    fn eigen_of_diagonal() {
        let a = Mat::diag(&[3.0, -1.0, 2.0]);
        let e = symmetric_eigen(&a);
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eigen_reconstructs() {
        let mut rng = Rng::seed_from(1);
        let g = Mat::gaussian(&mut rng, 16, 16, 1.0);
        let mut a = g.add(&g.transpose());
        a.scale(0.5);
        let e = symmetric_eigen(&a);
        // A v_i = w_i v_i
        for i in 0..16 {
            let vi: Vec<f64> = (0..16).map(|r| e.vectors[(r, i)]).collect();
            let av = a.matvec(&vi);
            for r in 0..16 {
                assert!((av[r] - e.values[i] * vi[r]).abs() < 1e-8, "i={i} r={r}");
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::seed_from(2);
        let g = Mat::gaussian(&mut rng, 12, 12, 1.0);
        let mut a = g.add(&g.transpose());
        a.scale(0.5);
        let e = symmetric_eigen(&a);
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.sub(&Mat::eye(12)).max_abs() < 1e-10);
    }

    #[test]
    fn apply_fn_sqrt() {
        let mut rng = Rng::seed_from(3);
        let g = Mat::gaussian(&mut rng, 20, 10, 1.0);
        let mut a = syrk_at_a(&g);
        a.add_diag(0.1);
        let e = symmetric_eigen(&a);
        let sq = e.apply_fn(|w| w.max(0.0).sqrt());
        let back = matmul(&sq, &sq);
        assert!(back.sub(&a).max_abs() < 1e-8);
    }

    #[test]
    fn apply_fn_inverse() {
        let mut rng = Rng::seed_from(4);
        let g = Mat::gaussian(&mut rng, 18, 9, 1.0);
        let mut a = syrk_at_a(&g);
        a.add_diag(0.5);
        let e = symmetric_eigen(&a);
        let inv = e.apply_fn(|w| 1.0 / w);
        assert!(matmul(&a, &inv).sub(&Mat::eye(9)).max_abs() < 1e-9);
    }

    #[test]
    fn cond_of_identity_is_one() {
        let e = symmetric_eigen(&Mat::eye(5));
        assert!((e.cond() - 1.0).abs() < 1e-12);
    }
}
