//! Dense linear algebra substrate.
//!
//! Everything the PRISM engines, baselines and optimizers need, built from
//! scratch: a row-major `f64` matrix type, blocked GEMM, norms, and the
//! classical decompositions (Cholesky, LU, Householder QR, cyclic-Jacobi
//! symmetric eigensolver, SVD).
//!
//! The layout is deliberately simple (one contiguous `Vec<f64>` per matrix);
//! the performance-critical kernels (GEMM and friends) live in [`gemm`]: a
//! packed, cache-blocked engine ([`gemm::GemmEngine`]) with 8×4
//! register-tiled microkernels dispatched at engine construction
//! ([`gemm::MicroKernel`]: portable scalar, AVX2+FMA, NEON — `--gemm-kernel`
//! on the CLI), skinny-operand fast paths (packed GEMV and thin-A/thin-B
//! streaming kernels for the sketch shapes), tunable block sizes
//! ([`gemm::GemmBlocking`], `--gemm-block` on the CLI), row-panel
//! parallelism over the crate's [`crate::threads::ThreadPool`]
//! (bit-identical at every pool size for a fixed kernel), `*_into`
//! out-parameter variants and a [`gemm::Workspace`] buffer pool so
//! iterative engines run allocation-free in their hot loops.
//!
//! A parallel `f32` instantiation ([`Mat32`], `GemmEngine::matmul_f32_into`
//! and friends, 8×8 f32 microkernels — 8 lanes/register on AVX2) backs the
//! mixed-precision solve path (`matfn` `Precision::Mixed`): the iteration
//! runs in f32 while the residual/stop guard stays in f64. See the [`gemm`]
//! and `crate::matfn` module docs for the accuracy contract.

pub mod gemm;
pub mod decomp;
pub mod eigen;
pub mod svd;
pub mod norms;

pub use gemm::{
    matmul, matmul_a_bt, matmul_at_b, syrk_a_at, syrk_at_a, GemmBlocking, GemmEngine, MicroKernel,
    Workspace,
};
pub use decomp::{
    cholesky, cholesky_inverse, lu_inverse, lu_solve, orthonormalize_columns, qr_householder,
};
pub use eigen::{symmetric_eigen, SymEigen};
pub use norms::{spectral_norm_est, spectral_norm_sym};
pub use svd::{svd, Svd};

use crate::rng::Rng;
use crate::util::{Error, Result};
use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_vec: {}x{} needs {} elems, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }
    /// Element capacity of the backing allocation (≥ rows·cols). Used by
    /// [`gemm::Workspace`] to hand out buffers that can be reshaped to a
    /// requested size without reallocating.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into `dst`, reshaping it to cols×rows and reusing its
    /// allocation — the workspace-friendly form of [`Mat::transpose`].
    pub fn transpose_into(&self, dst: &mut Mat) {
        dst.reset(self.cols, self.rows);
        // Blocked to keep both sides cache-friendly for large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        dst.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Reshape in place to rows×cols, reusing the existing allocation when
    /// it is large enough. Contents are **unspecified** afterwards — this is
    /// the buffer-recycling primitive behind [`gemm::Workspace`]; every
    /// `*_into` kernel overwrites the full output before reading it.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Set every entry to `v` (no allocation).
    pub fn fill_with(&mut self, v: f64) {
        for x in self.data.iter_mut() {
            *x = v;
        }
    }

    /// Become a copy of `src` (shape and contents), reusing the allocation.
    pub fn copy_from(&mut self, src: &Mat) {
        self.reset(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, s: f64) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// `self + s * other` (elementwise), in place.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Add `s` to the diagonal, in place (square only used in practice but
    /// works on the leading min(rows, cols) diagonal).
    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += s;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>()
    }

    /// Trace (square).
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }

    /// Max |a_ij|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
    }

    /// Whether any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Symmetry defect `max |A - Aᵀ|`.
    pub fn symmetry_defect(&self) -> f64 {
        assert!(self.is_square());
        let mut d = 0.0_f64;
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                d = d.max((self.data[i * self.cols + j] - self.data[j * self.cols + i]).abs());
            }
        }
        d
    }

    /// Force exact symmetry: `(A + Aᵀ)/2` in place.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let a = self.data[i * self.cols + j];
                let b = self.data[j * self.cols + i];
                let m = 0.5 * (a + b);
                self.data[i * self.cols + j] = m;
                self.data[j * self.cols + i] = m;
            }
        }
    }

    /// `A - B` as a new matrix.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `A + B` as a new matrix.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `s * A` as a new matrix.
    pub fn scaled(&self, s: f64) -> Mat {
        let data = self.data.iter().map(|a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// `Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for j in 0..self.cols {
                y[j] += row[j] * xi;
            }
        }
        y
    }

    /// Gaussian random matrix with entries N(0, sigma²).
    pub fn gaussian(rng: &mut Rng, rows: usize, cols: usize, sigma: f64) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal() * sigma).collect();
        Mat { rows, cols, data }
    }

    /// Copy a sub-block `[r0..r0+h) x [c0..c0+w)`.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Mat {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols);
        let mut out = Mat::zeros(h, w);
        for i in 0..h {
            out.row_mut(i)
                .copy_from_slice(&self.data[(r0 + i) * self.cols + c0..(r0 + i) * self.cols + c0 + w]);
        }
        out
    }

    /// Write a sub-block in place.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + b.cols].copy_from_slice(b.row(i));
        }
    }
}

/// Dense row-major `f32` matrix — the iterate storage of the mixed-precision
/// compute path (`Precision::Mixed`: f32 iteration, f64 residual guard).
///
/// Deliberately a small mirror of [`Mat`]: exactly what the f32 GEMM engine
/// ([`gemm::GemmEngine::matmul_f32_into`] and friends) and the
/// `prism::mixed` drivers need, plus exact up/down conversions. Every
/// f64→f32 downcast rounds to nearest; the f32→f64 upcast is exact, so the
/// f64 guard in the mixed drivers always sees precisely the iterate the f32
/// kernels produced.
#[derive(Clone, PartialEq)]
pub struct Mat32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat32 {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Downcast an f64 matrix (round to nearest).
    pub fn from_f64(m: &Mat) -> Self {
        let mut out = Mat32::zeros(0, 0);
        out.copy_from_f64(m);
        out
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
    /// Element capacity of the backing allocation (≥ rows·cols); the f32
    /// side of [`gemm::Workspace`] uses it exactly like [`Mat::capacity`].
    #[inline]
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// Reshape in place (contents unspecified afterwards) — the
    /// buffer-recycling primitive, mirroring [`Mat::reset`].
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Set every entry to `v` (no allocation).
    pub fn fill_with(&mut self, v: f32) {
        for x in self.data.iter_mut() {
            *x = v;
        }
    }

    /// Become a copy of `src`, reusing the allocation.
    pub fn copy_from(&mut self, src: &Mat32) {
        self.reset(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    /// Become the rounded-down copy of an f64 matrix, reusing the allocation
    /// — the workspace-friendly downcast the mixed drivers run per iteration.
    pub fn copy_from_f64(&mut self, src: &Mat) {
        self.reset(src.rows(), src.cols());
        for (d, &s) in self.data.iter_mut().zip(src.as_slice()) {
            *d = s as f32;
        }
    }

    /// Exact upcast into a caller-owned f64 buffer (reshaped in place).
    pub fn write_f64_into(&self, dst: &mut Mat) {
        dst.reset(self.rows, self.cols);
        for (d, &s) in dst.as_mut_slice().iter_mut().zip(&self.data) {
            *d = s as f64;
        }
    }

    /// Exact upcast as a new f64 matrix.
    pub fn to_f64(&self) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.write_f64_into(&mut out);
        out
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// `self + s * other` (elementwise), in place.
    pub fn axpy(&mut self, s: f32, other: &Mat32) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Add `s` to the leading diagonal, in place.
    pub fn add_diag(&mut self, s: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += s;
        }
    }

    /// Frobenius norm (accumulated in f64 so large matrices don't overflow
    /// or lose the low bits the mixed stall guard watches).
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |a_ij|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0_f32, |m, x| m.max(x.abs()))
    }

    /// Whether any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }
}

impl Index<(usize, usize)> for Mat32 {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat32 {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat32 {}x{}", self.rows, self.cols)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(6);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            if show_c < self.cols {
                write!(f, "...")?;
            }
            writeln!(f)?;
        }
        if show_r < self.rows {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_index() {
        let m = Mat::eye(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
        assert_eq!(m.trace(), 3.0);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Mat::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Mat::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let a = Mat::gaussian(&mut rng, 37, 53, 1.0);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_into_reuses_buffer() {
        let mut rng = Rng::seed_from(7);
        let a = Mat::gaussian(&mut rng, 9, 5, 1.0);
        let mut dst = Mat::zeros(1, 1);
        a.transpose_into(&mut dst);
        assert_eq!(dst.shape(), (5, 9));
        assert_eq!(dst, a.transpose());
        // And again with a bigger source into the now-larger buffer.
        let b = Mat::gaussian(&mut rng, 3, 4, 1.0);
        b.transpose_into(&mut dst);
        assert_eq!(dst, b.transpose());
    }

    #[test]
    fn reset_fill_copy_from() {
        let mut m = Mat::zeros(2, 3);
        m.reset(4, 2);
        assert_eq!(m.shape(), (4, 2));
        m.fill_with(1.5);
        assert_eq!(m[(3, 1)], 1.5);
        let src = Mat::eye(3);
        m.copy_from(&src);
        assert_eq!(m, src);
    }

    #[test]
    fn axpy_and_scale() {
        let a = Mat::eye(2);
        let mut b = Mat::zeros(2, 2);
        b.axpy(2.0, &a);
        assert_eq!(b[(0, 0)], 2.0);
        b.scale(0.5);
        assert_eq!(b[(1, 1)], 1.0);
    }

    #[test]
    fn fro_norm_eye() {
        let m = Mat::eye(4);
        assert!((m.fro_norm() - 2.0).abs() < 1e-14);
    }

    #[test]
    fn symmetrize_removes_defect() {
        let mut rng = Rng::seed_from(2);
        let mut a = Mat::gaussian(&mut rng, 8, 8, 1.0);
        assert!(a.symmetry_defect() > 0.0);
        a.symmetrize();
        assert_eq!(a.symmetry_defect(), 0.0);
    }

    #[test]
    fn matvec_identity() {
        let m = Mat::eye(3);
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&x), x);
        assert_eq!(m.matvec_t(&x), x);
    }

    #[test]
    fn block_get_set() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 10, 10, 1.0);
        let b = a.block(2, 3, 4, 5);
        assert_eq!(b.shape(), (4, 5));
        assert_eq!(b[(0, 0)], a[(2, 3)]);
        let mut c = Mat::zeros(10, 10);
        c.set_block(2, 3, &b);
        assert_eq!(c[(2, 3)], a[(2, 3)]);
        assert_eq!(c[(5, 7)], a[(5, 7)]);
        assert_eq!(c[(0, 0)], 0.0);
    }

    #[test]
    fn add_diag_works() {
        let mut m = Mat::zeros(3, 3);
        m.add_diag(2.5);
        assert_eq!(m.trace(), 7.5);
    }

    #[test]
    fn sub_add_scaled() {
        let a = Mat::eye(2);
        let b = a.scaled(3.0);
        assert_eq!(b[(0, 0)], 3.0);
        let c = b.sub(&a);
        assert_eq!(c[(0, 0)], 2.0);
        let d = c.add(&a);
        assert_eq!(d[(0, 0)], 3.0);
    }
}
