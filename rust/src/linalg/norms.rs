//! Spectral-norm estimation.
//!
//! The PRISM engines normalise inputs by `‖A‖_F` exactly as the paper does,
//! but the *analysis* (and several stopping rules) are in terms of `‖·‖₂`.
//! Power iteration gives cheap, GEMM-free estimates for diagnostics.

use super::Mat;
use crate::rng::Rng;

/// Estimate `‖A‖₂` for a general matrix by power iteration on `AᵀA`.
/// `iters` ~ 30 gives ~3 digits for well-separated spectra.
pub fn spectral_norm_est(a: &Mat, iters: usize, rng: &mut Rng) -> f64 {
    let n = a.cols();
    let mut v = rng.normal_vec(n);
    normalize(&mut v);
    let mut sigma = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        let atav = a.matvec_t(&av);
        sigma = norm(&atav).sqrt();
        v = atav;
        let nv = norm(&v);
        if nv < 1e-300 {
            return 0.0;
        }
        for x in v.iter_mut() {
            *x /= nv;
        }
    }
    sigma
}

/// Estimate `‖A‖₂ = max |λ|` for a **symmetric** matrix by power iteration.
pub fn spectral_norm_sym(a: &Mat, iters: usize, rng: &mut Rng) -> f64 {
    assert!(a.is_square());
    let n = a.rows();
    let mut v = rng.normal_vec(n);
    normalize(&mut v);
    let mut lam = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        lam = dot(&av, &v).abs();
        let nv = norm(&av);
        if nv < 1e-300 {
            return 0.0;
        }
        v = av;
        for x in v.iter_mut() {
            *x /= nv;
        }
    }
    // Last Rayleigh quotient refinement.
    let av = a.matvec(&v);
    lam = lam.max(norm(&av));
    lam
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 1e-300 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;

    #[test]
    fn sym_norm_of_diag() {
        let a = Mat::diag(&[1.0, -5.0, 3.0]);
        let mut rng = Rng::seed_from(1);
        let est = spectral_norm_sym(&a, 100, &mut rng);
        assert!((est - 5.0).abs() < 1e-6, "est={est}");
    }

    #[test]
    fn general_norm_matches_svd() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 20, 12, 1.0);
        let smax = svd(&a).s[0];
        let est = spectral_norm_est(&a, 200, &mut rng);
        assert!((est - smax).abs() / smax < 1e-3, "est={est} smax={smax}");
    }

    #[test]
    fn zero_matrix_norm_zero() {
        let a = Mat::zeros(5, 5);
        let mut rng = Rng::seed_from(3);
        assert_eq!(spectral_norm_est(&a, 10, &mut rng), 0.0);
        assert_eq!(spectral_norm_sym(&a, 10, &mut rng), 0.0);
    }
}
