//! Skinny-operand fast paths: packed GEMV and thin-A/thin-B kernels for
//! products whose smallest dimension fits inside one micro-tile.
//!
//! The blocked path (see [`super::parallel`]) packs **both** operands into
//! panels — the right trade when the O(mnk) kernel work amortises the
//! O(mk + kn) copies. For skinny products it is exactly wrong: a `p×n ·
//! n×n` sketch propagation with `p ≤ MR` would copy the *dominant* operand
//! (all of B, NR-padded) to feed at most one A panel, and a 1-column GEMV
//! would pack the whole of A (zero-padded to NR columns of B by
//! `GemmBlocking::clamped`'s NC ≥ NR floor) to compute m dot products.
//! These paths instead pack only the *small* operand — once, zero-padded,
//! k-major — and stream the large one straight from its buffer, so the
//! dominant operand is read exactly once with no copy:
//!
//! * [`thin_a`] (`m ≤ MR`, which includes the `m == 1` row-GEMV): A packed
//!   into a single MR-row panel; B streamed. Used by the sketch power
//!   traces (`p×n · n×n`) and the polyfit assembly in `prism::fit`.
//! * [`thin_b`] (`n ≤ NR`, which includes the `n == 1` column-GEMV): B
//!   packed into a single NR-column panel; A streamed row by row.
//!
//! Routing (in [`super::GemmEngine`]) depends only on the shape and operand
//! forms — never on thread count, blocking, or the selected microkernel —
//! so every engine configuration takes the same path and per-element
//! accumulation stays a single k-ordered chain: results are bit-identical
//! across pool sizes *and* across blockings for skinny shapes. [`thin_a`]
//! has at most MR rows and runs on the calling thread; [`thin_b`] can be
//! arbitrarily tall, so it splits C's rows over the engine's pool through
//! the same [`split_row_panels`] partition as the blocked path — each row
//! is an independent k-ordered dot against the shared packed B panel, so
//! the partition cannot change any output bit. The inner loops are
//! dependence-free over the packed lane dimension, which LLVM
//! auto-vectorises (the [`super::MicroKernel`] choice does not apply here).
// The tag below marks this file hot-path for `cargo xtask lint` (rule R3):
// no allocating constructors or allocating matmuls may appear in it — the
// single small-operand panel comes from the engine's `Workspace` pool.
#![doc = "hot-path"]

use super::kernel::{MR, MR32, NR, NR32};
use super::pack::{pack_a, pack_a32, pack_b, pack_b32};
use super::parallel::split_row_panels;
use super::{Operand, PACK_WS};
use crate::threads::ThreadPool;

/// `C[m×n] += op(A)·op(B)` for `m ≤ MR`. A is packed once into a single
/// zero-padded MR-row k-major panel; B is streamed unpacked. Per-element
/// accumulation order is pure k order in every branch.
pub(super) fn thin_a(a: Operand<'_>, b: Operand<'_>, c: &mut [f64], m: usize, n: usize, k: usize) {
    debug_assert!((1..=MR).contains(&m));
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take(1, k * MR);
        pack_a(apack.as_mut_slice(), a, 0, m, 0, k);
        let ap = apack.as_slice();
        if b.cs == 1 {
            // Row-major B: stream its rows once, t-outer; each k-step is m
            // broadcast-axpys onto the L2-resident C rows.
            for t in 0..k {
                let at = &ap[t * MR..t * MR + MR];
                let brow = &b.data[t * b.rs..t * b.rs + n];
                for (r, &ar) in at.iter().enumerate().take(m) {
                    let crow = &mut c[r * n..r * n + n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += ar * bv;
                    }
                }
            }
        } else {
            // Column-strided B (a transposed view): walk j-major so the
            // underlying buffer streams contiguously; the packed A panel
            // (≤ MR·k doubles) is the only operand re-read per column.
            for j in 0..n {
                let mut acc = [0.0f64; MR];
                if b.rs == 1 {
                    let bcol = &b.data[j * b.cs..j * b.cs + k];
                    for (t, &bv) in bcol.iter().enumerate() {
                        let at = &ap[t * MR..t * MR + MR];
                        for (av, &ar) in acc.iter_mut().zip(at) {
                            *av += ar * bv;
                        }
                    }
                } else {
                    for t in 0..k {
                        let bv = b.at(t, j);
                        let at = &ap[t * MR..t * MR + MR];
                        for (av, &ar) in acc.iter_mut().zip(at) {
                            *av += ar * bv;
                        }
                    }
                }
                for (r, &av) in acc.iter().enumerate().take(m) {
                    c[r * n + j] += av;
                }
            }
        }
        ws.put(apack);
    });
}

/// `C[m×n] += op(A)·op(B)` for `n ≤ NR`. B is packed once into a single
/// zero-padded NR-column k-major panel (≤ NR·k doubles, cache-resident);
/// A is streamed one row at a time and read exactly once. The NR-wide
/// accumulator runs full width — padded lanes carry exact zeros and are
/// clipped at store — so the inner loop is one 4-lane FMA per k-step.
///
/// Unlike `thin_a`, m can be arbitrarily large (a tall GEMV), so C's rows
/// are split over `pool` when it pays: every worker reads the same packed
/// B panel and computes its rows' independent k-ordered dots, keeping the
/// result bit-identical for every pool size.
#[allow(clippy::too_many_arguments)]
pub(super) fn thin_b(
    pool: Option<&ThreadPool>,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert!((1..=NR).contains(&n));
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut bpack = ws.take(1, k * NR);
        pack_b(bpack.as_mut_slice(), b, 0, k, 0, n);
        let bp = bpack.as_slice();
        split_row_panels(pool, c, m, n, &|cpanel, i0, rows| {
            thin_b_rows(a, bp, cpanel, i0, rows, n, k)
        });
        ws.put(bpack);
    });
}

/// Rows `i0..i0+rows` of the thin-B product: each row of C is an NR-wide
/// accumulation over the shared packed B panel `bp`, in pure k order.
fn thin_b_rows(
    a: Operand<'_>,
    bp: &[f64],
    c: &mut [f64],
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
) {
    for ri in 0..rows {
        let i = i0 + ri;
        let mut acc = [0.0f64; NR];
        if a.cs == 1 {
            let arow = &a.data[i * a.rs..i * a.rs + k];
            for (t, &av) in arow.iter().enumerate() {
                let bt = &bp[t * NR..t * NR + NR];
                for (cj, &bj) in acc.iter_mut().zip(bt) {
                    *cj += av * bj;
                }
            }
        } else {
            for t in 0..k {
                let av = a.at(i, t);
                let bt = &bp[t * NR..t * NR + NR];
                for (cj, &bj) in acc.iter_mut().zip(bt) {
                    *cj += av * bj;
                }
            }
        }
        let crow = &mut c[ri * n..ri * n + n];
        for (cv, &av) in crow.iter_mut().zip(&acc) {
            *cv += av;
        }
    }
}

// ───────────────────────── f32 twins ─────────────────────────
//
// Same shape thresholds against the f32 tile grid (`MR32`/`NR32`), same
// single-k-chain accumulation order, same "pack only the small operand"
// trade. Routed by `GemmEngine::dispatch32`.

/// f32 twin of [`thin_a`]: `C[m×n] += op(A)·op(B)` for `m ≤ MR32`.
pub(super) fn thin_a32(
    a: Operand<'_, f32>,
    b: Operand<'_, f32>,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert!((1..=MR32).contains(&m));
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take_f32(1, k * MR32);
        pack_a32(apack.as_mut_slice(), a, 0, m, 0, k);
        let ap = apack.as_slice();
        if b.cs == 1 {
            for t in 0..k {
                let at = &ap[t * MR32..t * MR32 + MR32];
                let brow = &b.data[t * b.rs..t * b.rs + n];
                for (r, &ar) in at.iter().enumerate().take(m) {
                    let crow = &mut c[r * n..r * n + n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += ar * bv;
                    }
                }
            }
        } else {
            for j in 0..n {
                let mut acc = [0.0f32; MR32];
                if b.rs == 1 {
                    let bcol = &b.data[j * b.cs..j * b.cs + k];
                    for (t, &bv) in bcol.iter().enumerate() {
                        let at = &ap[t * MR32..t * MR32 + MR32];
                        for (av, &ar) in acc.iter_mut().zip(at) {
                            *av += ar * bv;
                        }
                    }
                } else {
                    for t in 0..k {
                        let bv = b.at(t, j);
                        let at = &ap[t * MR32..t * MR32 + MR32];
                        for (av, &ar) in acc.iter_mut().zip(at) {
                            *av += ar * bv;
                        }
                    }
                }
                for (r, &av) in acc.iter().enumerate().take(m) {
                    c[r * n + j] += av;
                }
            }
        }
        ws.put_f32(apack);
    });
}

/// f32 twin of [`thin_b`]: `C[m×n] += op(A)·op(B)` for `n ≤ NR32`, row-split
/// over the pool through the shared [`split_row_panels`] partition.
#[allow(clippy::too_many_arguments)]
pub(super) fn thin_b32(
    pool: Option<&ThreadPool>,
    a: Operand<'_, f32>,
    b: Operand<'_, f32>,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert!((1..=NR32).contains(&n));
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut bpack = ws.take_f32(1, k * NR32);
        pack_b32(bpack.as_mut_slice(), b, 0, k, 0, n);
        let bp = bpack.as_slice();
        split_row_panels(pool, c, m, n, &|cpanel, i0, rows| {
            thin_b32_rows(a, bp, cpanel, i0, rows, n, k)
        });
        ws.put_f32(bpack);
    });
}

/// f32 twin of [`thin_b_rows`].
fn thin_b32_rows(
    a: Operand<'_, f32>,
    bp: &[f32],
    c: &mut [f32],
    i0: usize,
    rows: usize,
    n: usize,
    k: usize,
) {
    for ri in 0..rows {
        let i = i0 + ri;
        let mut acc = [0.0f32; NR32];
        if a.cs == 1 {
            let arow = &a.data[i * a.rs..i * a.rs + k];
            for (t, &av) in arow.iter().enumerate() {
                let bt = &bp[t * NR32..t * NR32 + NR32];
                for (cj, &bj) in acc.iter_mut().zip(bt) {
                    *cj += av * bj;
                }
            }
        } else {
            for t in 0..k {
                let av = a.at(i, t);
                let bt = &bp[t * NR32..t * NR32 + NR32];
                for (cj, &bj) in acc.iter_mut().zip(bt) {
                    *cj += av * bj;
                }
            }
        }
        let crow = &mut c[ri * n..ri * n + n];
        for (cv, &av) in crow.iter_mut().zip(&acc) {
            *cv += av;
        }
    }
}
