//! Row-panel scheduling: partition the rows of C over the engine's thread
//! pool and drive the blocked packed kernel on each panel.
//!
//! Determinism invariant (what makes the parallel row split exact): for any
//! fixed element `(i, j)`, the accumulation is "for each (NC, KC) block in
//! grid order: add a register-accumulated k-ordered partial sum". The row
//! partition and the MC/MR grids decide only *which tile* computes an
//! element, never the order of its additions, so callers may split rows
//! anywhere — results are **bit-identical for every pool size** at a fixed
//! ([`GemmBlocking`], [`MicroKernel`]) pair. Zero-padding keeps edge tiles
//! on the same code path.
// The tag below marks this file hot-path for `cargo xtask lint` (rule R3):
// no allocating constructors or allocating matmuls may appear in it — panels
// come from the engine's `Workspace` pool, never fresh `Vec`s.
#![doc = "hot-path"]

use super::kernel::{micro_tile, micro_tile32, MicroKernel, MR, MR32, NR, NR32};
use super::pack::{pack_a, pack_a32, pack_b, pack_b32};
use super::{GemmBlocking, Operand, PACK_WS};
use crate::threads::{scoped, ThreadPool};

/// Minimum C rows per parallel panel — below this the dispatch overhead
/// beats the kernel time, so small products stay sequential.
const MIN_PANEL_ROWS: usize = 16;

/// Split C's rows into contiguous panels over `pool` and run
/// `body(cpanel, i0, rows)` on each — sequentially (one whole-C panel)
/// when the pool is absent or the product too small to split. The one
/// row-partition heuristic shared by the blocked path and the thin-B
/// skinny path (both dtypes), so the routes can never silently diverge.
pub(super) fn split_row_panels<E: Send>(
    pool: Option<&ThreadPool>,
    c: &mut [E],
    m: usize,
    n: usize,
    body: &(dyn Fn(&mut [E], usize, usize) + Sync),
) {
    // Floor division: never split below MIN_PANEL_ROWS rows per panel
    // (a sub-minimum panel pays dispatch overhead for no kernel time).
    let threads = pool.map(|p| p.size()).unwrap_or(1);
    let blocks = threads.min(m / MIN_PANEL_ROWS).max(1);
    match pool {
        Some(pool) if blocks > 1 => {
            let rows_per = m.div_ceil(blocks);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = c
                .chunks_mut(rows_per * n)
                .enumerate()
                .map(|(bi, cpanel)| {
                    let i0 = bi * rows_per;
                    let rows = cpanel.len() / n;
                    Box::new(move || body(cpanel, i0, rows))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            scoped(pool, jobs);
        }
        _ => body(c, 0, m),
    }
}

/// Run the blocked packed kernel over C's rows: sequentially when `pool` is
/// `None` (or the product is too small to split), otherwise on contiguous
/// row panels over the pool.
#[allow(clippy::too_many_arguments)]
pub(super) fn row_panels(
    pool: Option<&ThreadPool>,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    m: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    split_row_panels(pool, c, m, n, &|cpanel, i0, rows| {
        gemm_panel(a, b, cpanel, i0, i0 + rows, n, k, blk, kern, upper_only)
    });
}

/// Sequential packed kernel over one row panel of C (`rows pi0..pi1`, all n
/// columns; `c` is that panel's row-major storage). `upper_only` skips
/// micro-tiles strictly below the diagonal — used by SYRK; the skipped
/// entries (and any sub-diagonal entries a straddling tile does produce)
/// are overwritten by the caller's mirror pass.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    pi0: usize,
    pi1: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    if pi0 >= pi1 || n == 0 || k == 0 {
        return;
    }
    let GemmBlocking { mc, kc, nc } = blk;
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take(1, mc.div_ceil(MR) * MR * kc);
        let mut bpack = ws.take(1, nc.div_ceil(NR) * NR * kc);
        for jc in (0..n).step_by(nc) {
            let j1 = (jc + nc).min(n);
            // SYRK: a row panel entirely below this column block has no
            // upper-triangle work at all — skip before packing any B panel.
            if upper_only && pi0 >= j1 {
                continue;
            }
            for k0 in (0..k).step_by(kc) {
                let k1 = (k0 + kc).min(k);
                let kb = k1 - k0;
                pack_b(bpack.as_mut_slice(), b, k0, k1, jc, j1);
                for ic in (pi0..pi1).step_by(mc) {
                    let i1 = (ic + mc).min(pi1);
                    // SYRK: a whole A block strictly below this column block
                    // contributes no upper-triangle element — skip it before
                    // paying for the pack.
                    if upper_only && ic >= j1 {
                        continue;
                    }
                    pack_a(apack.as_mut_slice(), a, ic, i1, k0, k1);
                    let mut si = 0;
                    let mut js = jc;
                    while js < j1 {
                        let w = NR.min(j1 - js);
                        let bstrip = &bpack.as_slice()[si * kb * NR..(si + 1) * kb * NR];
                        let mut tile = 0;
                        let mut ti = ic;
                        while ti < i1 {
                            let h = MR.min(i1 - ti);
                            // Upper-triangle filter at micro-tile grain: a
                            // tile whose first row is past the strip's last
                            // column holds no (i ≤ j) element. The test uses
                            // global indices, so every upper element is
                            // computed under any row partition.
                            if !upper_only || ti < js + NR {
                                let astrip =
                                    &apack.as_slice()[tile * kb * MR..(tile + 1) * kb * MR];
                                let acc = micro_tile(kern, kb, astrip, bstrip);
                                for r in 0..h {
                                    let base = (ti - pi0 + r) * n + js;
                                    let row = &mut c[base..base + w];
                                    for j in 0..w {
                                        row[j] += acc[r * NR + j];
                                    }
                                }
                            }
                            tile += 1;
                            ti += MR;
                        }
                        si += 1;
                        js += NR;
                    }
                }
            }
        }
        ws.put(apack);
        ws.put(bpack);
    });
}

/// f32 twin of [`row_panels`]: same row-partition heuristic (shared
/// [`split_row_panels`]), same determinism invariant — bit-identical for
/// every pool size at a fixed ([`GemmBlocking`], [`MicroKernel`]) pair.
#[allow(clippy::too_many_arguments)]
pub(super) fn row_panels32(
    pool: Option<&ThreadPool>,
    a: Operand<'_, f32>,
    b: Operand<'_, f32>,
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    split_row_panels(pool, c, m, n, &|cpanel, i0, rows| {
        gemm_panel32(a, b, cpanel, i0, i0 + rows, n, k, blk, kern, upper_only)
    });
}

/// f32 twin of [`gemm_panel`] over `MR32×NR32` tiles. Pack buffers come
/// from the f32 side of the thread-local [`super::Workspace`]; the blocking
/// is the caller's (already clamped to the f32 tile grid by `dispatch32`).
#[allow(clippy::too_many_arguments)]
fn gemm_panel32(
    a: Operand<'_, f32>,
    b: Operand<'_, f32>,
    c: &mut [f32],
    pi0: usize,
    pi1: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    if pi0 >= pi1 || n == 0 || k == 0 {
        return;
    }
    let GemmBlocking { mc, kc, nc } = blk;
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take_f32(1, mc.div_ceil(MR32) * MR32 * kc);
        let mut bpack = ws.take_f32(1, nc.div_ceil(NR32) * NR32 * kc);
        for jc in (0..n).step_by(nc) {
            let j1 = (jc + nc).min(n);
            if upper_only && pi0 >= j1 {
                continue;
            }
            for k0 in (0..k).step_by(kc) {
                let k1 = (k0 + kc).min(k);
                let kb = k1 - k0;
                pack_b32(bpack.as_mut_slice(), b, k0, k1, jc, j1);
                for ic in (pi0..pi1).step_by(mc) {
                    let i1 = (ic + mc).min(pi1);
                    if upper_only && ic >= j1 {
                        continue;
                    }
                    pack_a32(apack.as_mut_slice(), a, ic, i1, k0, k1);
                    let mut si = 0;
                    let mut js = jc;
                    while js < j1 {
                        let w = NR32.min(j1 - js);
                        let bstrip = &bpack.as_slice()[si * kb * NR32..(si + 1) * kb * NR32];
                        let mut tile = 0;
                        let mut ti = ic;
                        while ti < i1 {
                            let h = MR32.min(i1 - ti);
                            if !upper_only || ti < js + NR32 {
                                let astrip =
                                    &apack.as_slice()[tile * kb * MR32..(tile + 1) * kb * MR32];
                                let acc = micro_tile32(kern, kb, astrip, bstrip);
                                for r in 0..h {
                                    let base = (ti - pi0 + r) * n + js;
                                    let row = &mut c[base..base + w];
                                    for j in 0..w {
                                        row[j] += acc[r * NR32 + j];
                                    }
                                }
                            }
                            tile += 1;
                            ti += MR32;
                        }
                        si += 1;
                        js += NR32;
                    }
                }
            }
        }
        ws.put_f32(apack);
        ws.put_f32(bpack);
    });
}
