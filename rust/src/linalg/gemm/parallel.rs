//! Row-panel scheduling: partition the rows of C over the engine's thread
//! pool and drive the blocked packed kernel on each panel.
//!
//! Determinism invariant (what makes the parallel row split exact): for any
//! fixed element `(i, j)`, the accumulation is "for each (NC, KC) block in
//! grid order: add a register-accumulated k-ordered partial sum". The row
//! partition and the MC/MR grids decide only *which tile* computes an
//! element, never the order of its additions, so callers may split rows
//! anywhere — results are **bit-identical for every pool size** at a fixed
//! ([`GemmBlocking`], [`MicroKernel`]) pair. Zero-padding keeps edge tiles
//! on the same code path.

use super::kernel::{micro_tile, MicroKernel, MR, NR};
use super::pack::{pack_a, pack_b};
use super::{GemmBlocking, Operand, PACK_WS};
use crate::threads::{scoped, ThreadPool};

/// Minimum C rows per parallel panel — below this the dispatch overhead
/// beats the kernel time, so small products stay sequential.
const MIN_PANEL_ROWS: usize = 16;

/// Split C's rows into contiguous panels over `pool` and run
/// `body(cpanel, i0, rows)` on each — sequentially (one whole-C panel)
/// when the pool is absent or the product too small to split. The one
/// row-partition heuristic shared by the blocked path and the thin-B
/// skinny path, so the two can never silently diverge.
pub(super) fn split_row_panels(
    pool: Option<&ThreadPool>,
    c: &mut [f64],
    m: usize,
    n: usize,
    body: &(dyn Fn(&mut [f64], usize, usize) + Sync),
) {
    // Floor division: never split below MIN_PANEL_ROWS rows per panel
    // (a sub-minimum panel pays dispatch overhead for no kernel time).
    let threads = pool.map(|p| p.size()).unwrap_or(1);
    let blocks = threads.min(m / MIN_PANEL_ROWS).max(1);
    match pool {
        Some(pool) if blocks > 1 => {
            let rows_per = m.div_ceil(blocks);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = c
                .chunks_mut(rows_per * n)
                .enumerate()
                .map(|(bi, cpanel)| {
                    let i0 = bi * rows_per;
                    let rows = cpanel.len() / n;
                    Box::new(move || body(cpanel, i0, rows))
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            scoped(pool, jobs);
        }
        _ => body(c, 0, m),
    }
}

/// Run the blocked packed kernel over C's rows: sequentially when `pool` is
/// `None` (or the product is too small to split), otherwise on contiguous
/// row panels over the pool.
#[allow(clippy::too_many_arguments)]
pub(super) fn row_panels(
    pool: Option<&ThreadPool>,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    m: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    split_row_panels(pool, c, m, n, &|cpanel, i0, rows| {
        gemm_panel(a, b, cpanel, i0, i0 + rows, n, k, blk, kern, upper_only)
    });
}

/// Sequential packed kernel over one row panel of C (`rows pi0..pi1`, all n
/// columns; `c` is that panel's row-major storage). `upper_only` skips
/// micro-tiles strictly below the diagonal — used by SYRK; the skipped
/// entries (and any sub-diagonal entries a straddling tile does produce)
/// are overwritten by the caller's mirror pass.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    pi0: usize,
    pi1: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    kern: MicroKernel,
    upper_only: bool,
) {
    if pi0 >= pi1 || n == 0 || k == 0 {
        return;
    }
    let GemmBlocking { mc, kc, nc } = blk;
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take(1, mc.div_ceil(MR) * MR * kc);
        let mut bpack = ws.take(1, nc.div_ceil(NR) * NR * kc);
        for jc in (0..n).step_by(nc) {
            let j1 = (jc + nc).min(n);
            // SYRK: a row panel entirely below this column block has no
            // upper-triangle work at all — skip before packing any B panel.
            if upper_only && pi0 >= j1 {
                continue;
            }
            for k0 in (0..k).step_by(kc) {
                let k1 = (k0 + kc).min(k);
                let kb = k1 - k0;
                pack_b(bpack.as_mut_slice(), b, k0, k1, jc, j1);
                for ic in (pi0..pi1).step_by(mc) {
                    let i1 = (ic + mc).min(pi1);
                    // SYRK: a whole A block strictly below this column block
                    // contributes no upper-triangle element — skip it before
                    // paying for the pack.
                    if upper_only && ic >= j1 {
                        continue;
                    }
                    pack_a(apack.as_mut_slice(), a, ic, i1, k0, k1);
                    let mut si = 0;
                    let mut js = jc;
                    while js < j1 {
                        let w = NR.min(j1 - js);
                        let bstrip = &bpack.as_slice()[si * kb * NR..(si + 1) * kb * NR];
                        let mut tile = 0;
                        let mut ti = ic;
                        while ti < i1 {
                            let h = MR.min(i1 - ti);
                            // Upper-triangle filter at micro-tile grain: a
                            // tile whose first row is past the strip's last
                            // column holds no (i ≤ j) element. The test uses
                            // global indices, so every upper element is
                            // computed under any row partition.
                            if !upper_only || ti < js + NR {
                                let astrip =
                                    &apack.as_slice()[tile * kb * MR..(tile + 1) * kb * MR];
                                let acc = micro_tile(kern, kb, astrip, bstrip);
                                for r in 0..h {
                                    let base = (ti - pi0 + r) * n + js;
                                    let row = &mut c[base..base + w];
                                    for j in 0..w {
                                        row[j] += acc[r * NR + j];
                                    }
                                }
                            }
                            tile += 1;
                            ti += MR;
                        }
                        si += 1;
                        js += NR;
                    }
                }
            }
        }
        ws.put(apack);
        ws.put(bpack);
    });
}
