//! Microkernels: the innermost 8×4 register tile of the packed GEMM engine,
//! in one portable form and one SIMD form per supported ISA, plus the
//! reference/ablation kernels ([`gemm_broadcast`], [`matmul_naive`]).
//!
//! # The microkernel contract
//!
//! Every kernel computes the same mathematical object: an `MR×NR` tile
//! `acc[r][j] = Σ_t ap[t·MR + r] · bp[t·NR + j]` over `kb` k-steps of two
//! **packed, k-major, zero-padded** panels (see [`super::pack`]). Each
//! `acc[r][j]` is a single serial accumulation chain in `t` order — no
//! kernel reassociates the reduction — so for a fixed kernel the result is
//! a pure function of the panels, independent of thread count or row
//! partition. Kernels may differ from each other in low-order bits:
//! the SIMD kernels use fused multiply-add (one rounding per step) where
//! the scalar kernel rounds the product and the sum separately. Per-kernel
//! determinism is guaranteed; **cross-kernel bit equality is not**.
//!
//! # `unsafe` invariants of the intrinsic kernels
//!
//! The AVX2 and NEON kernels are `unsafe fn` for exactly two reasons, and
//! both obligations are discharged structurally:
//!
//! 1. **ISA availability** (`#[target_feature]`): the kernel must only run
//!    on a CPU with the feature. [`MicroKernel::is_available`] gates every
//!    selection site — auto-detection ([`MicroKernel::detect`]), forced
//!    selection ([`super::GemmEngine::with_kernel`] asserts it), and the
//!    `PALLAS_GEMM_KERNEL` env override (falls back to detection).
//! 2. **In-bounds pointer arithmetic**: each kernel asserts
//!    `ap.len() ≥ kb·MR` and `bp.len() ≥ kb·NR` on entry; the packers
//!    zero-pad ragged panel tails to full `MR`/`NR` width, so every load in
//!    the k-loop is in bounds and edge tiles take no special path. All
//!    vector loads/stores are the unaligned variants (`loadu`/`vld1q`), so
//!    the panels only need `f64` alignment, which `Vec<f64>` guarantees.

use crate::linalg::{Mat, Mat32};
use crate::util::{Error, Result};

/// Microkernel register tile: MR rows of A × NR columns of B per inner-loop
/// step (MR·NR = 32 independent accumulator chains).
pub(crate) const MR: usize = 8;
pub(crate) const NR: usize = 4;

/// Which 8×4 microkernel the blocked GEMM path dispatches to. Selected once
/// at engine construction (or process-globally): `auto` picks the widest
/// kernel the host supports, `--gemm-kernel {auto,scalar,avx2,neon}` /
/// `service.gemm_kernel` / [`super::set_global_kernel`] force one for
/// ablations and CI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroKernel {
    /// Portable Rust 8×4 kernel (LLVM auto-vectorises the NR loop).
    Scalar,
    /// `core::arch::x86_64` AVX2+FMA kernel: one `__m256d` accumulator per
    /// A-row, 8 vector FMAs per k-step.
    Avx2,
    /// `core::arch::aarch64` NEON kernel: two `float64x2_t` accumulators per
    /// A-row, 16 vector FMAs per k-step.
    Neon,
}

impl MicroKernel {
    pub fn name(&self) -> &'static str {
        match self {
            MicroKernel::Scalar => "scalar",
            MicroKernel::Avx2 => "avx2",
            MicroKernel::Neon => "neon",
        }
    }

    /// Parse a `--gemm-kernel` / `service.gemm_kernel` /
    /// `PALLAS_GEMM_KERNEL` spec. `auto` (or empty) means "detect at
    /// startup" and parses to `None`; unknown names are errors listing the
    /// valid options.
    pub fn parse(s: &str) -> Result<Option<MicroKernel>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(None),
            "scalar" | "portable" => Ok(Some(MicroKernel::Scalar)),
            "avx2" => Ok(Some(MicroKernel::Avx2)),
            "neon" => Ok(Some(MicroKernel::Neon)),
            other => Err(Error::Parse(format!(
                "unknown gemm kernel '{other}' (want auto|scalar|avx2|neon)"
            ))),
        }
    }

    /// Whether this kernel can run on the current host (compile-time ISA
    /// plus, for AVX2, runtime feature detection). `Scalar` is always
    /// available; every selection path checks this before installing a
    /// kernel, which is what makes calling the `unsafe` intrinsics sound.
    pub fn is_available(&self) -> bool {
        match self {
            MicroKernel::Scalar => true,
            // Miri interprets MIR and has no shims for vendor SIMD
            // intrinsics; declaring the SIMD kernels unavailable under it
            // routes every selection path (detect/forced/env) onto the
            // scalar kernel, which is the path the nightly miri CI job
            // exercises.
            MicroKernel::Avx2 => !cfg!(miri) && avx2_available(),
            // NEON is a baseline aarch64 feature — no runtime probe needed.
            MicroKernel::Neon => !cfg!(miri) && cfg!(target_arch = "aarch64"),
        }
    }

    /// The widest kernel available on this host.
    pub fn detect() -> MicroKernel {
        if MicroKernel::Avx2.is_available() {
            MicroKernel::Avx2
        } else if MicroKernel::Neon.is_available() {
            MicroKernel::Neon
        } else {
            MicroKernel::Scalar
        }
    }

    /// Every kernel that can run on this host (always includes `Scalar`).
    /// The conformance suite and the `perf_gemm` ablation iterate this.
    pub fn available() -> Vec<MicroKernel> {
        [MicroKernel::Scalar, MicroKernel::Avx2, MicroKernel::Neon]
            .into_iter()
            .filter(|k| k.is_available())
            .collect()
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// Run one `MR×NR` micro-tile on the selected kernel. The match is a
/// perfectly predicted 2–3-way branch per tile — noise next to the
/// `kb·MR·NR` multiply-adds behind it. An ISA-gated variant that cannot be
/// compiled on this target falls through to the scalar kernel; the
/// availability checks at every selection site keep that arm from being
/// reached in practice (and it would still be correct if it were).
#[inline(always)]
pub(super) fn micro_tile(kern: MicroKernel, kb: usize, ap: &[f64], bp: &[f64]) -> [f64; MR * NR] {
    match kern {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only installed after `is_available()` confirmed
        // AVX2+FMA at runtime (see the module docs); bounds are asserted
        // inside the kernel.
        MicroKernel::Avx2 => unsafe { micro_tile_avx2(kb, ap, bp) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Neon` is only selectable on aarch64, where NEON is a
        // baseline feature; bounds are asserted inside the kernel.
        MicroKernel::Neon => unsafe { micro_tile_neon(kb, ap, bp) },
        _ => micro_tile_scalar(kb, ap, bp),
    }
}

/// Portable 8×4 microkernel. All 32 accumulators are independent and the
/// two operand streams are contiguous, so LLVM keeps `acc` in vector
/// registers and turns the inner `j` loop into FMAs (no float-reassociation
/// licence needed — each `acc[r][j]` is its own serial chain).
#[inline(always)]
fn micro_tile_scalar(kb: usize, ap: &[f64], bp: &[f64]) -> [f64; MR * NR] {
    let mut acc = [0.0f64; MR * NR];
    let ap = &ap[..kb * MR];
    let bp = &bp[..kb * NR];
    for t in 0..kb {
        let at = &ap[t * MR..t * MR + MR];
        let bt = &bp[t * NR..t * NR + NR];
        for r in 0..MR {
            let ar = at[r];
            for j in 0..NR {
                acc[r * NR + j] += ar * bt[j];
            }
        }
    }
    acc
}

/// AVX2+FMA 8×4 microkernel: `acc[r]` is one `__m256d` holding the tile's
/// r-th row; each k-step broadcasts `a[r]` and issues one fused
/// multiply-add per row (8 FMAs per step).
///
/// # Safety
///
/// Caller must ensure the host supports AVX2 and FMA (checked by
/// [`MicroKernel::is_available`] at every selection site). In-bounds access
/// is self-enforced: the entry assertions plus the packers' zero-padded
/// tails guarantee every `loadu` reads `kb·MR`/`kb·NR` valid elements;
/// unaligned loads/stores mean no alignment obligation beyond `f64`'s.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn micro_tile_avx2(kb: usize, ap: &[f64], bp: &[f64]) -> [f64; MR * NR] {
    use core::arch::x86_64::{
        __m256d, _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_set1_pd, _mm256_setzero_pd,
        _mm256_storeu_pd,
    };
    assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
    // SAFETY: the ISA obligation is the caller's (function-level contract
    // above). Every pointer offset is in bounds: `t < kb`, so reads stay
    // below `kb·MR` / `kb·NR` — covered by the entry assertion — and the
    // stores cover exactly the `MR·NR` array; loads/stores are the
    // unaligned variants.
    unsafe {
        let zero = _mm256_setzero_pd();
        let mut acc: [__m256d; MR] = [zero; MR];
        for t in 0..kb {
            let bv = _mm256_loadu_pd(bp.as_ptr().add(t * NR));
            let at = ap.as_ptr().add(t * MR);
            for (r, accr) in acc.iter_mut().enumerate() {
                *accr = _mm256_fmadd_pd(_mm256_set1_pd(*at.add(r)), bv, *accr);
            }
        }
        let mut out = [0.0f64; MR * NR];
        for (r, accr) in acc.iter().enumerate() {
            _mm256_storeu_pd(out.as_mut_ptr().add(r * NR), *accr);
        }
        out
    }
}

/// NEON 8×4 microkernel: the tile's r-th row is a `float64x2_t` pair
/// (`lo[r]`, `hi[r]`); each k-step issues two `vfmaq_n_f64` per row
/// (16 vector FMAs per step).
///
/// # Safety
///
/// aarch64-only (`cfg`-gated), where NEON is a baseline feature, so the
/// `target_feature` obligation holds on every aarch64 host. Bounds are
/// asserted on entry and the packers zero-pad panel tails, keeping every
/// `vld1q_f64`/`vst1q_f64` in bounds; both are unaligned-capable.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn micro_tile_neon(kb: usize, ap: &[f64], bp: &[f64]) -> [f64; MR * NR] {
    use core::arch::aarch64::{vdupq_n_f64, vfmaq_n_f64, vld1q_f64, vst1q_f64};
    assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
    // SAFETY: NEON is baseline on aarch64 (function-level contract). Every
    // offset is in bounds per the entry assertion (`t < kb`; NR = 4, so
    // `t·NR + 2 + 2 ≤ kb·NR`), and the stores tile the `MR·NR` array in
    // disjoint 2-lane pairs.
    unsafe {
        let zero = vdupq_n_f64(0.0);
        let mut lo = [zero; MR];
        let mut hi = [zero; MR];
        for t in 0..kb {
            let b0 = vld1q_f64(bp.as_ptr().add(t * NR));
            let b1 = vld1q_f64(bp.as_ptr().add(t * NR + 2));
            let at = ap.as_ptr().add(t * MR);
            for r in 0..MR {
                let ar = *at.add(r);
                lo[r] = vfmaq_n_f64(lo[r], b0, ar);
                hi[r] = vfmaq_n_f64(hi[r], b1, ar);
            }
        }
        let mut out = [0.0f64; MR * NR];
        for r in 0..MR {
            vst1q_f64(out.as_mut_ptr().add(r * NR), lo[r]);
            vst1q_f64(out.as_mut_ptr().add(r * NR + 2), hi[r]);
        }
        out
    }
}

// ───────────────────── f32 microkernel family ─────────────────────
//
// The mixed-precision solve path iterates in f32; these are the 8×8 f32
// twins of the kernels above, dispatched by the same `MicroKernel` enum.
// NR32 = 8 (not 4) because one f32 SIMD register holds 8 lanes on AVX2 —
// the whole point of the f32 path is doubling lanes per register. The
// microkernel contract is identical: packed k-major zero-padded panels,
// one serial accumulation chain per `acc[r][j]`, per-kernel determinism,
// no cross-kernel (or cross-dtype) bit equality.

/// f32 microkernel register tile: 8 rows × 8 columns (one full `__m256`
/// B-vector per k-step on AVX2).
pub(crate) const MR32: usize = 8;
pub(crate) const NR32: usize = 8;

/// Run one `MR32×NR32` f32 micro-tile on the selected kernel. Same
/// dispatch/fallback structure as [`micro_tile`].
#[inline(always)]
pub(super) fn micro_tile32(
    kern: MicroKernel,
    kb: usize,
    ap: &[f32],
    bp: &[f32],
) -> [f32; MR32 * NR32] {
    match kern {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` is only installed after `is_available()` confirmed
        // AVX2+FMA at runtime (see the module docs); bounds are asserted
        // inside the kernel.
        MicroKernel::Avx2 => unsafe { micro_tile32_avx2(kb, ap, bp) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Neon` is only selectable on aarch64, where NEON is a
        // baseline feature; bounds are asserted inside the kernel.
        MicroKernel::Neon => unsafe { micro_tile32_neon(kb, ap, bp) },
        _ => micro_tile32_scalar(kb, ap, bp),
    }
}

/// Portable 8×8 f32 microkernel — structurally identical to
/// [`micro_tile_scalar`] with the wider NR32 inner loop.
#[inline(always)]
fn micro_tile32_scalar(kb: usize, ap: &[f32], bp: &[f32]) -> [f32; MR32 * NR32] {
    let mut acc = [0.0f32; MR32 * NR32];
    let ap = &ap[..kb * MR32];
    let bp = &bp[..kb * NR32];
    for t in 0..kb {
        let at = &ap[t * MR32..t * MR32 + MR32];
        let bt = &bp[t * NR32..t * NR32 + NR32];
        for r in 0..MR32 {
            let ar = at[r];
            for j in 0..NR32 {
                acc[r * NR32 + j] += ar * bt[j];
            }
        }
    }
    acc
}

/// AVX2+FMA 8×8 f32 microkernel: `acc[r]` is one `__m256` (8 f32 lanes)
/// holding the tile's r-th row; each k-step broadcasts `a[r]` and issues
/// one fused multiply-add per row — 8 FMAs per step, each over 8 lanes,
/// twice the per-register throughput of the f64 kernel.
///
/// # Safety
///
/// Same obligations as [`micro_tile_avx2`]: AVX2+FMA must be present
/// (gated by [`MicroKernel::is_available`] at every selection site), and
/// in-bounds access is self-enforced via the entry assertions plus the
/// packers' zero-padded tails; unaligned loads/stores mean no alignment
/// obligation beyond `f32`'s.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn micro_tile32_avx2(kb: usize, ap: &[f32], bp: &[f32]) -> [f32; MR32 * NR32] {
    use core::arch::x86_64::{
        __m256, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };
    assert!(ap.len() >= kb * MR32 && bp.len() >= kb * NR32);
    // SAFETY: same shape as `micro_tile_avx2` — ISA is the caller's
    // contract, offsets stay below `kb·MR32` / `kb·NR32` per the entry
    // assertion, the stores cover exactly the `MR32·NR32` array, and all
    // loads/stores are unaligned variants.
    unsafe {
        let zero = _mm256_setzero_ps();
        let mut acc: [__m256; MR32] = [zero; MR32];
        for t in 0..kb {
            let bv = _mm256_loadu_ps(bp.as_ptr().add(t * NR32));
            let at = ap.as_ptr().add(t * MR32);
            for (r, accr) in acc.iter_mut().enumerate() {
                *accr = _mm256_fmadd_ps(_mm256_set1_ps(*at.add(r)), bv, *accr);
            }
        }
        let mut out = [0.0f32; MR32 * NR32];
        for (r, accr) in acc.iter().enumerate() {
            _mm256_storeu_ps(out.as_mut_ptr().add(r * NR32), *accr);
        }
        out
    }
}

/// NEON 8×8 f32 microkernel: the tile's r-th row is a `float32x4_t` pair
/// (`lo[r]`, `hi[r]`); each k-step issues two `vfmaq_n_f32` per row
/// (16 vector FMAs per step, each over 4 lanes).
///
/// # Safety
///
/// Same obligations as [`micro_tile_neon`]: aarch64-only (`cfg`-gated),
/// bounds asserted on entry, zero-padded panel tails keep every
/// `vld1q_f32`/`vst1q_f32` in bounds.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn micro_tile32_neon(kb: usize, ap: &[f32], bp: &[f32]) -> [f32; MR32 * NR32] {
    use core::arch::aarch64::{vdupq_n_f32, vfmaq_n_f32, vld1q_f32, vst1q_f32};
    assert!(ap.len() >= kb * MR32 && bp.len() >= kb * NR32);
    // SAFETY: same shape as `micro_tile_neon` — NEON is baseline on
    // aarch64; offsets are in bounds per the entry assertion (`t < kb`;
    // NR32 = 8, so `t·NR32 + 4 + 4 ≤ kb·NR32`), and the stores tile the
    // `MR32·NR32` array in disjoint 4-lane pairs.
    unsafe {
        let zero = vdupq_n_f32(0.0);
        let mut lo = [zero; MR32];
        let mut hi = [zero; MR32];
        for t in 0..kb {
            let b0 = vld1q_f32(bp.as_ptr().add(t * NR32));
            let b1 = vld1q_f32(bp.as_ptr().add(t * NR32 + 4));
            let at = ap.as_ptr().add(t * MR32);
            for r in 0..MR32 {
                let ar = *at.add(r);
                lo[r] = vfmaq_n_f32(lo[r], b0, ar);
                hi[r] = vfmaq_n_f32(hi[r], b1, ar);
            }
        }
        let mut out = [0.0f32; MR32 * NR32];
        for r in 0..MR32 {
            vst1q_f32(out.as_mut_ptr().add(r * NR32), lo[r]);
            vst1q_f32(out.as_mut_ptr().add(r * NR32 + 4), hi[r]);
        }
        out
    }
}

// ───────────────── reference / ablation kernels ──────────────────

/// The seed's broadcast-FMA kernel: `C[m x n] += A[m x k] · B[k x n]`, both
/// row-major. Kept as the §Perf ablation baseline (`perf_gemm` reports the
/// packed kernels' speedups over it) and as a second independent
/// implementation for conformance cross-checks.
///
/// Loop order (jc, kc, i, t, j): the innermost `crow[j] += a_it * brow[j]`
/// has no cross-iteration dependence, so rustc vectorises it into FMAs. The
/// (KC2 × NC) B panel stays hot in L2 across the whole i sweep; a 4-row
/// micro-tile quarters the B bandwidth. Unlike the packed kernels it never
/// copies its operands — which is exactly what costs it at large n: A and C
/// rows are touched with stride n, so TLB/cache-line utilisation degrades
/// where the packed kernels keep streaming contiguous panels.
pub fn gemm_broadcast(a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    const NC: usize = 512; // B-panel columns (NC·KC2·8B = 512 KiB ≤ L2)
    const KC2: usize = 256; // B-panel rows
    for j0 in (0..n).step_by(NC) {
        let j1 = (j0 + NC).min(n);
        for k0 in (0..k).step_by(KC2) {
            let k1 = (k0 + KC2).min(k);
            let mut i = 0;
            while i + 4 <= m {
                let (rows01, rows23) = (&mut c[i * n..(i + 4) * n]).split_at_mut(2 * n);
                let (row0, row1) = rows01.split_at_mut(n);
                let (row2, row3) = rows23.split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let c2 = &mut row2[j0..j1];
                let c3 = &mut row3[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for t in k0..k1 {
                    let (av0, av1, av2, av3) = (a0[t], a1[t], a2[t], a3[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((((c0v, c1v), c2v), c3v), bv) in c0
                        .iter_mut()
                        .zip(c1.iter_mut())
                        .zip(c2.iter_mut())
                        .zip(c3.iter_mut())
                        .zip(brow)
                    {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                        *c2v += av2 * bv;
                        *c3v += av3 * bv;
                    }
                }
                i += 4;
            }
            while i + 2 <= m {
                let (row0, row1) = (&mut c[i * n..(i + 2) * n]).split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                for t in k0..k1 {
                    let (av0, av1) = (a0[t], a1[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((c0v, c1v), bv) in c0.iter_mut().zip(c1.iter_mut()).zip(brow) {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                    }
                }
                i += 2;
            }
            if i < m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for t in k0..k1 {
                    let av = a[i * k + t];
                    let brow = &b[t * n + j0..t * n + j1];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// Reference (naive) matmul for tests.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for t in 0..k {
            let av = a[(i, t)];
            for j in 0..n {
                c[(i, j)] += av * b[(t, j)];
            }
        }
    }
    c
}

/// Reference (naive) f32 matmul for the dtype conformance axis. Accumulates
/// in f32 (same arithmetic class as the packed f32 kernels) so comparisons
/// measure reassociation/FMA differences, not a precision gap.
pub fn matmul_naive32(a: &Mat32, b: &Mat32) -> Mat32 {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat32::zeros(m, n);
    for i in 0..m {
        for t in 0..k {
            let av = a[(i, t)];
            for j in 0..n {
                c[(i, j)] += av * b[(t, j)];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn parse_and_name_round_trip() {
        assert_eq!(MicroKernel::parse("auto").unwrap(), None);
        assert_eq!(MicroKernel::parse("").unwrap(), None);
        for k in [MicroKernel::Scalar, MicroKernel::Avx2, MicroKernel::Neon] {
            assert_eq!(MicroKernel::parse(k.name()).unwrap(), Some(k));
        }
        assert_eq!(MicroKernel::parse("AVX2").unwrap(), Some(MicroKernel::Avx2));
        assert!(MicroKernel::parse("sse9").is_err());
        let err = MicroKernel::parse("sse9").unwrap_err().to_string();
        assert!(err.contains("scalar"), "{err}");
    }

    #[test]
    fn scalar_always_available_and_detect_is_available() {
        assert!(MicroKernel::Scalar.is_available());
        assert!(MicroKernel::detect().is_available());
        let avail = MicroKernel::available();
        assert!(avail.contains(&MicroKernel::Scalar));
        assert!(avail.contains(&MicroKernel::detect()));
    }

    #[test]
    fn micro_tiles32_agree_with_scalar() {
        // f32 twin of `micro_tiles_agree_with_scalar`, at f32 round-off.
        let mut rng = Rng::seed_from(2);
        for kb in [1usize, 2, 7, 33] {
            let ap: Vec<f32> = (0..kb * MR32).map(|_| rng.normal() as f32).collect();
            let bp: Vec<f32> = (0..kb * NR32).map(|_| rng.normal() as f32).collect();
            let want = micro_tile32(MicroKernel::Scalar, kb, &ap, &bp);
            for kern in MicroKernel::available() {
                let got = micro_tile32(kern, kb, &ap, &bp);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4, "{} kb={kb}: {g} vs {w}", kern.name());
                }
            }
        }
    }

    #[test]
    fn micro_tiles_agree_with_scalar() {
        // Every available SIMD kernel must match the scalar kernel on the
        // same packed panels to fp64 round-off (FMA keeps them from being
        // bit-identical — documented; cross-kernel bit equality is NOT part
        // of the contract).
        let mut rng = Rng::seed_from(1);
        for kb in [1usize, 2, 7, 33] {
            let ap: Vec<f64> = (0..kb * MR).map(|_| rng.normal()).collect();
            let bp: Vec<f64> = (0..kb * NR).map(|_| rng.normal()).collect();
            let want = micro_tile(MicroKernel::Scalar, kb, &ap, &bp);
            for kern in MicroKernel::available() {
                let got = micro_tile(kern, kb, &ap, &bp);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-12, "{} kb={kb}: {g} vs {w}", kern.name());
                }
            }
        }
    }
}
