//! Packed, cache-blocked, parallel GEMM and symmetric rank-k engine with
//! runtime-dispatched SIMD microkernels and skinny-operand fast paths.
//!
//! This is the O(n³) hot path of every Newton–Schulz-like iteration. The
//! module is a small tree, one file per layer:
//!
//! * **`mod.rs`** (this file) — the public API: [`GemmEngine`], the
//!   [`Workspace`] buffer pool, the [`GemmBlocking`]/[`MicroKernel`] knobs
//!   and their process-global defaults, GEMM-call accounting
//!   ([`GemmCounter`]/[`GemmScope`]), and the shape-based **dispatch** that
//!   routes each product to the blocked or skinny path.
//! * **[`pack`]** — panel packing: cache blocks of the (possibly strided)
//!   operands are copied into contiguous k-major panels, zero-padded to
//!   full MR(=8)-row / NR(=4)-column tiles. Packing reads sources through
//!   (row, col) strides, so `AᵀB`, `ABᵀ` and both SYRKs run the same
//!   kernels **without materialising any transpose**.
//! * **[`kernel`]** — the 8×4 microkernels behind [`MicroKernel`]: the
//!   portable scalar kernel, an AVX2+FMA kernel (`core::arch::x86_64`),
//!   and a NEON kernel (`core::arch::aarch64`), plus the reference
//!   kernels [`gemm_broadcast`] and [`matmul_naive`]. The `unsafe`
//!   invariants of the intrinsic kernels (ISA availability, zero-padded
//!   panel bounds, no alignment requirement) are documented there.
//! * **[`parallel`]** — row-panel scheduling: C's rows are partitioned into
//!   contiguous panels over the crate's [`crate::threads::ThreadPool`], and
//!   each panel runs the three blocking loops (NC × KC × MC) around the
//!   dispatched microkernel.
//! * **[`skinny`]** — fast paths for products whose smallest dimension fits
//!   inside one micro-tile: a packed GEMV (`n == 1` / `m == 1`) and
//!   thin-A/thin-B kernels that pack only the small operand and stream the
//!   dominant one exactly once (the sketch path `p×n · n×n`, p ≤ 8).
//!
//! # Dispatch rules
//!
//! Each call resolves its configuration once — blocking from
//! [`GemmEngine::with_blocking`] or [`global_blocking`], microkernel from
//! [`GemmEngine::with_kernel`] or [`global_kernel`] — then routes purely on
//! shape and operand form:
//!
//! 1. `m == 0 || n == 0 || k == 0` → nothing to do.
//! 2. general products with `m ≤ MR` → [`skinny::thin_a`]; `n ≤ NR` →
//!    [`skinny::thin_b`] (SYRK always takes the blocked path — its
//!    upper-triangle filter lives there).
//! 3. everything else → the blocked path, row-panel parallel when the
//!    engine has a pool and `m` is large enough to split.
//!
//! Routing never depends on thread count, blocking, or kernel, so every
//! engine configuration agrees on the path taken. `GemmBlocking`'s
//! micro-tile floors (MC ≥ MR, NC ≥ NR) therefore apply only where the
//! blocked path's panel grid exists: a 1-column GEMV no longer packs the
//! whole of A into MR-padded panels under an NR-widened B block — the
//! skinny path packs only the tiny k×NR B panel (its last NR−1 lanes
//! zero-padded; ≤ 4k doubles, cache-resident) and streams A uncopied.
//! Tall thin-B products still split their rows over the engine's pool.
//!
//! # Kernel selection
//!
//! [`MicroKernel`] is a startup-time knob with the same contract as the
//! blocking: `auto` (the default) picks the widest kernel the host
//! supports via `is_x86_feature_detected!` (NEON is baseline on aarch64);
//! `--gemm-kernel {auto,scalar,avx2,neon}` on the CLI,
//! `service.gemm_kernel` in TOML, the `PALLAS_GEMM_KERNEL` env var (read
//! once, for CI matrices), or [`GemmEngine::with_kernel`] per engine force
//! a variant for ablations and tests. Results are bit-identical across
//! pool sizes *for a fixed kernel*; kernels may differ from each other in
//! low-order bits (FMA fuses the product-add rounding), so cross-kernel
//! bit equality is explicitly **not** part of the contract — conformance
//! cross-checks run at tolerance instead.
//!
//! # Workspaces
//!
//! `*_into` variants write into caller-owned output buffers (reshaped in
//! place, allocation reused). [`Workspace`] is a best-fit buffer pool for
//! iteration temporaries; the packing buffers are drawn from a per-thread
//! [`Workspace`] of their own and reused across calls, so steady-state GEMM
//! traffic performs **zero heap allocation** (the iteration engines'
//! ping-pong buffers and the sketch panels are likewise pooled, asserted by
//! the tier-1/matfn allocation tests).
//!
//! GEMM-call counting: the PRISM paper reports costs in units of GEMMs; the
//! engines count their invocations through [`GemmCounter`]. Counts are kept
//! both process-globally and per-thread; [`GemmScope`] reads the per-thread
//! counters so concurrent runs (service workers, parallel tests) never see
//! each other's calls. SYRK records its true n²k flop count — the mirrored
//! half is a copy, not recomputation — and is additionally tallied under
//! [`GemmCounter::syrk_calls`] so cost models can separate the two shapes.
//!
//! # The f32 instantiation (dtype axis)
//!
//! Every layer above has an f32 twin — [`GemmEngine::matmul_f32_into`] /
//! [`GemmEngine::syrk_at_a_f32_into`] over [`Mat32`], routed by the same
//! shape rules through 8×8 f32 microkernels (`MR32 = NR32 = 8`: one f32
//! SIMD register holds 8 lanes on AVX2, doubling per-register FMA
//! throughput over the f64 kernels — the raw-speed lever behind the
//! mixed-precision solve path). The twins share the [`MicroKernel`] /
//! [`GemmBlocking`] knobs, the thread-local pack workspace (its f32 side),
//! [`GemmCounter`] accounting, and the determinism contract: bit-identical
//! across pool sizes for a fixed (blocking, kernel) pair, per-dtype.
//! **No accuracy contract ties the two dtypes together at this layer** —
//! an f32 product carries f32 round-off (~1e-7 relative, growing with k);
//! the dtype conformance grid compares f32 paths against [`matmul_naive32`]
//! at a widened tolerance, and the *solver-level* guarantee (f64-grade
//! stopping decisions over f32 iterates) is made one level up, in
//! `prism::mixed` / the `matfn` module docs.

mod kernel;
mod pack;
mod parallel;
mod skinny;

pub use kernel::{gemm_broadcast, matmul_naive, matmul_naive32, MicroKernel};
pub(crate) use kernel::{MR, NR};
use kernel::{MR32, NR32};

use super::{Mat, Mat32};
// `Mutex` comes from the shim (not `std::sync`) so the `--cfg loom` build —
// which swaps the shim's `Mutex` for the model checker's — still compiles
// this module; `lock_or_recover` is typed against the shim's mutex.
use crate::runtime::sync::{Arc, Mutex, OnceLock};
use crate::threads::ThreadPool;
use crate::util::{lock_or_recover, Error, Result};
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

/// Process-wide GEMM counters (cheap relaxed atomics) plus thread-local
/// shadows for race-free per-run accounting.
static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_FLOPS: AtomicU64 = AtomicU64::new(0);
static SYRK_CALLS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL_CALLS: Cell<u64> = const { Cell::new(0) };
    static TL_FLOPS: Cell<u64> = const { Cell::new(0) };
    static TL_SYRK: Cell<u64> = const { Cell::new(0) };
}

pub struct GemmCounter;

impl GemmCounter {
    /// Process-wide call count (all threads, GEMM + SYRK).
    pub fn calls() -> u64 {
        GEMM_CALLS.load(Ordering::Relaxed)
    }
    /// Process-wide flop count (all threads).
    pub fn flops() -> u64 {
        GEMM_FLOPS.load(Ordering::Relaxed)
    }
    /// Process-wide SYRK call count (a subset of [`GemmCounter::calls`]).
    pub fn syrk_calls() -> u64 {
        SYRK_CALLS.load(Ordering::Relaxed)
    }
    fn add(calls: u64, flops: u64, syrk: u64) {
        GEMM_CALLS.fetch_add(calls, Ordering::Relaxed);
        GEMM_FLOPS.fetch_add(flops, Ordering::Relaxed);
        if syrk > 0 {
            SYRK_CALLS.fetch_add(syrk, Ordering::Relaxed);
            TL_SYRK.with(|c| c.set(c.get() + syrk));
        }
        TL_CALLS.with(|c| c.set(c.get() + calls));
        TL_FLOPS.with(|c| c.set(c.get() + flops));
    }
    /// One general GEMM: 2mnk flops.
    fn record(m: usize, n: usize, k: usize) {
        Self::add(1, 2 * (m as u64) * (n as u64) * (k as u64), 0);
    }
    /// One SYRK: the symmetric result costs n²k flops (half a GEMM — the
    /// mirrored half is produced by copying the upper triangle).
    fn record_syrk(n: usize, k: usize) {
        Self::add(1, (n as u64) * (n as u64) * (k as u64), 1);
    }
}

/// Scoped snapshot of the **current thread's** GEMM counters. Deltas are
/// immune to concurrent GEMMs on other threads (recording happens on the
/// calling thread even when the kernel itself runs on the pool), so
/// iteration logs and parallel tests never race on the globals.
pub struct GemmScope {
    calls0: u64,
    flops0: u64,
    syrk0: u64,
}

impl GemmScope {
    pub fn begin() -> GemmScope {
        GemmScope {
            calls0: TL_CALLS.with(|c| c.get()),
            flops0: TL_FLOPS.with(|c| c.get()),
            syrk0: TL_SYRK.with(|c| c.get()),
        }
    }
    /// GEMM + SYRK calls made by this thread since [`GemmScope::begin`].
    pub fn calls(&self) -> u64 {
        TL_CALLS.with(|c| c.get()) - self.calls0
    }
    /// Flops recorded by this thread since [`GemmScope::begin`].
    pub fn flops(&self) -> u64 {
        TL_FLOPS.with(|c| c.get()) - self.flops0
    }
    /// SYRK calls made by this thread since [`GemmScope::begin`] (each is
    /// also included in [`GemmScope::calls`]).
    pub fn syrk_calls(&self) -> u64 {
        TL_SYRK.with(|c| c.get()) - self.syrk0
    }
}

// ───────────────────────── workspace ──────────────────────────

/// A small pool of reusable matrix buffers. `take` hands out (and reshapes)
/// a previously returned buffer or allocates a fresh one; `put` returns a
/// buffer for reuse. Contents of a taken buffer are unspecified — every
/// `*_into` kernel overwrites its full output.
///
/// `take` is **best-fit**: it hands out the *smallest* free buffer whose
/// backing allocation already fits the request, so a pool serving mixed
/// sizes (an engine's n×n ping-pong buffers next to the sketch path's p×n
/// panels and 1×q trace rows) never gives a large buffer to a small request
/// and then has to grow a small buffer for a large one. A steady state of
/// same-shape take/put cycles therefore performs **zero heap allocations**.
/// [`Workspace::allocations`] counts the takes that could *not* be served
/// from the pool — the persistent-solver tests assert it stays flat from
/// the second same-shape call onward.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Mat>,
    /// f32 side of the pool (mixed-precision iterates and f32 pack panels).
    /// Separate free list — an f32 request must never repurpose an f64
    /// allocation or vice versa — but one shared `allocs` counter, so the
    /// allocation-free-hot-loop assertions cover both dtypes at once.
    free32: Vec<Mat32>,
    allocs: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a rows×cols buffer (contents unspecified).
    pub fn take(&mut self, rows: usize, cols: usize) -> Mat {
        let need = rows * cols;
        // Best fit: smallest free buffer that already holds `need` elems.
        let mut best: Option<(usize, usize)> = None;
        for (i, m) in self.free.iter().enumerate() {
            let cap = m.capacity();
            let better = match best {
                None => cap >= need,
                Some((_, c)) => cap >= need && cap < c,
            };
            if better {
                best = Some((i, cap));
            }
        }
        if let Some((i, _)) = best {
            let mut m = self.free.swap_remove(i);
            m.reset(rows, cols);
            return m;
        }
        // Miss: grow the largest free buffer (least new memory) or allocate.
        self.allocs += 1;
        let grow = self
            .free
            .iter()
            .enumerate()
            .max_by_key(|(_, m)| m.capacity())
            .map(|(i, _)| i);
        match grow {
            Some(i) => {
                let mut m = self.free.swap_remove(i);
                m.reset(rows, cols);
                m
            }
            None => Mat::zeros(rows, cols),
        }
    }

    /// Return a buffer to the pool for later reuse.
    pub fn put(&mut self, m: Mat) {
        self.free.push(m);
    }

    /// Take a rows×cols **f32** buffer (contents unspecified) — same
    /// best-fit policy as [`Workspace::take`], over the f32 free list.
    pub fn take_f32(&mut self, rows: usize, cols: usize) -> Mat32 {
        let need = rows * cols;
        let mut best: Option<(usize, usize)> = None;
        for (i, m) in self.free32.iter().enumerate() {
            let cap = m.capacity();
            let better = match best {
                None => cap >= need,
                Some((_, c)) => cap >= need && cap < c,
            };
            if better {
                best = Some((i, cap));
            }
        }
        if let Some((i, _)) = best {
            let mut m = self.free32.swap_remove(i);
            m.reset(rows, cols);
            return m;
        }
        self.allocs += 1;
        let grow = self
            .free32
            .iter()
            .enumerate()
            .max_by_key(|(_, m)| m.capacity())
            .map(|(i, _)| i);
        match grow {
            Some(i) => {
                let mut m = self.free32.swap_remove(i);
                m.reset(rows, cols);
                m
            }
            None => Mat32::zeros(rows, cols),
        }
    }

    /// Return an f32 buffer to the pool for later reuse.
    pub fn put_f32(&mut self, m: Mat32) {
        self.free32.push(m);
    }

    /// Number of takes that had to allocate (or grow) because no free buffer
    /// was large enough. Flat across calls ⇔ the hot path is allocation-free.
    pub fn allocations(&self) -> usize {
        self.allocs
    }

    /// Number of idle buffers held.
    pub fn len(&self) -> usize {
        self.free.len()
    }
    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

thread_local! {
    /// Per-thread pool for the packing buffers: each pool worker (and the
    /// caller, on the sequential and skinny paths) reuses its own buffers
    /// across every GEMM it runs, so steady-state packing is
    /// allocation-free without any cross-thread sharing.
    static PACK_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

// ───────────────────────── blocking knobs ──────────────────────────

/// Cache-block sizes of the blocked packed path (see the module docs for
/// the cache-level rationale behind the defaults). The skinny paths ignore
/// these — they pack at most one panel and stream the other operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmBlocking {
    /// Rows of A per packed block (L2 resident together with one B panel).
    pub mc: usize,
    /// Shared-dimension extent per packed block. **Changing KC regroups the
    /// reduction** (one register-accumulated partial sum per KC block), so
    /// it may change low-order result bits; fix it once at startup.
    pub kc: usize,
    /// Columns of B per packed block (same bit-level caveat as `kc`).
    pub nc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        GemmBlocking { mc: 128, kc: 256, nc: 512 }
    }
}

impl GemmBlocking {
    /// Parse a `MCxKCxNC` spec, e.g. `128x256x512` (`,` also accepted as the
    /// separator). All three must be ≥ 1.
    pub fn parse(s: &str) -> Result<GemmBlocking> {
        let parts: Vec<&str> = s.split(['x', 'X', ',']).map(str::trim).collect();
        if parts.len() != 3 {
            return Err(Error::Parse(format!(
                "gemm blocking '{s}': expected MCxKCxNC (e.g. 128x256x512)"
            )));
        }
        let mut v = [0usize; 3];
        for (slot, p) in v.iter_mut().zip(&parts) {
            *slot = p
                .parse::<usize>()
                .ok()
                .filter(|&x| x >= 1)
                .ok_or_else(|| Error::Parse(format!("gemm blocking '{s}': bad size '{p}'")))?;
        }
        Ok(GemmBlocking { mc: v[0], kc: v[1], nc: v[2] })
    }

    /// Render back to the `MCxKCxNC` form `parse` accepts.
    pub fn display(&self) -> String {
        format!("{}x{}x{}", self.mc, self.kc, self.nc)
    }

    /// Blocking with the micro-tile minimums enforced (MC ≥ MR, NC ≥ NR).
    /// Applied only where panels exist — on the blocked path. The skinny
    /// paths route *before* clamping, so the NC ≥ NR floor never forces a
    /// 1-column GEMV to pack NR-padded B columns (the regression the
    /// dims-of-one conformance tests pin down).
    fn clamped(self) -> GemmBlocking {
        GemmBlocking { mc: self.mc.max(MR), kc: self.kc.max(1), nc: self.nc.max(NR) }
    }

    /// f32-grid variant of [`GemmBlocking::clamped`]: the f32 micro-tile is
    /// `MR32×NR32` (8×8), so the NC floor is 8, not the f64 path's 4.
    fn clamped32(self) -> GemmBlocking {
        GemmBlocking { mc: self.mc.max(MR32), kc: self.kc.max(1), nc: self.nc.max(NR32) }
    }
}

/// Process-global blocking, stored as three atomics so reading it is free of
/// locks on the per-GEMM path. Each kernel invocation snapshots it once.
static GLOBAL_MC: AtomicUsize = AtomicUsize::new(128);
static GLOBAL_KC: AtomicUsize = AtomicUsize::new(256);
static GLOBAL_NC: AtomicUsize = AtomicUsize::new(512);

/// Install process-global cache-block sizes (`--gemm-block` on the CLI,
/// `service.gemm_block` in TOML). A startup-time tuning knob: changing KC/NC
/// regroups reductions and may change low-order bits of later results, so
/// set it before computing anything you intend to compare bitwise.
pub fn set_global_blocking(b: GemmBlocking) {
    let b = b.clamped();
    GLOBAL_MC.store(b.mc, Ordering::Relaxed);
    GLOBAL_KC.store(b.kc, Ordering::Relaxed);
    GLOBAL_NC.store(b.nc, Ordering::Relaxed);
}

/// Current process-global cache-block sizes.
pub fn global_blocking() -> GemmBlocking {
    GemmBlocking {
        mc: GLOBAL_MC.load(Ordering::Relaxed),
        kc: GLOBAL_KC.load(Ordering::Relaxed),
        nc: GLOBAL_NC.load(Ordering::Relaxed),
    }
}

// ───────────────────────── kernel knob ──────────────────────────

/// Process-global kernel override: 0 = unset (auto-detect), else the
/// encoded [`MicroKernel`]. Read lock-free on the per-GEMM path.
static GLOBAL_KERNEL: AtomicU8 = AtomicU8::new(0);

fn encode_kernel(k: MicroKernel) -> u8 {
    match k {
        MicroKernel::Scalar => 1,
        MicroKernel::Avx2 => 2,
        MicroKernel::Neon => 3,
    }
}

fn decode_kernel(v: u8) -> Option<MicroKernel> {
    match v {
        1 => Some(MicroKernel::Scalar),
        2 => Some(MicroKernel::Avx2),
        3 => Some(MicroKernel::Neon),
        _ => None,
    }
}

/// Install a process-global microkernel (`--gemm-kernel` on the CLI,
/// `service.gemm_kernel` in TOML); `None` returns to auto-detection. Like
/// the blocking, a startup-time knob: kernels agree to fp64 round-off but
/// not bit-for-bit (FMA), so switch before computing anything you intend to
/// compare bitwise.
///
/// # Panics
///
/// If the kernel is not available on this host — callers (CLI, service
/// config) check [`MicroKernel::is_available`] first and report the error
/// on their own channel.
pub fn set_global_kernel(k: Option<MicroKernel>) {
    match k {
        Some(k) => {
            assert!(
                k.is_available(),
                "gemm kernel '{}' is not available on this host",
                k.name()
            );
            GLOBAL_KERNEL.store(encode_kernel(k), Ordering::Relaxed);
        }
        None => GLOBAL_KERNEL.store(0, Ordering::Relaxed),
    }
}

/// The microkernel engines run with when no per-engine override is set:
/// the global override if installed, otherwise the auto-detected default
/// (which itself honours `PALLAS_GEMM_KERNEL`, read once per process).
pub fn global_kernel() -> MicroKernel {
    decode_kernel(GLOBAL_KERNEL.load(Ordering::Relaxed)).unwrap_or_else(auto_kernel)
}

/// The auto-detected kernel, resolved once per process. `PALLAS_GEMM_KERNEL`
/// overrides detection (the CI matrix forces `scalar` through it so the
/// portable path stays green on SIMD-capable runners); an unavailable or
/// malformed value falls back to detection with a warning on stderr.
fn auto_kernel() -> MicroKernel {
    static AUTO: OnceLock<MicroKernel> = OnceLock::new();
    *AUTO.get_or_init(|| match std::env::var("PALLAS_GEMM_KERNEL") {
        Ok(v) => match MicroKernel::parse(&v) {
            Ok(Some(k)) if k.is_available() => k,
            Ok(Some(k)) => {
                eprintln!(
                    "PALLAS_GEMM_KERNEL={v}: kernel '{}' not available on this host; auto-detecting",
                    k.name()
                );
                MicroKernel::detect()
            }
            Ok(None) => MicroKernel::detect(),
            Err(e) => {
                eprintln!("PALLAS_GEMM_KERNEL: {e}; auto-detecting");
                MicroKernel::detect()
            }
        },
        Err(_) => MicroKernel::detect(),
    })
}

// ───────────────────────── engine ──────────────────────────

/// A strided read-only view of one GEMM operand: element `(i, j)` lives at
/// `data[i·rs + j·cs]`. Lets the packing routines and the skinny kernels
/// serve `A`, `Aᵀ`, `B`, `Bᵀ` from the original buffers — no transpose is
/// ever materialised. Generic over the element type (`f64` default, `f32`
/// for the mixed-precision path); the constructors are dtype-specific and
/// distinctly named so call sites never rely on inference.
#[derive(Clone, Copy)]
struct Operand<'a, E = f64> {
    data: &'a [E],
    rs: usize,
    cs: usize,
}

impl<'a, E: Copy> Operand<'a, E> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> E {
        self.data[i * self.rs + j * self.cs]
    }
}

impl<'a> Operand<'a, f64> {
    fn normal(m: &'a Mat) -> Operand<'a, f64> {
        Operand { data: m.as_slice(), rs: m.cols(), cs: 1 }
    }
    fn transposed(m: &'a Mat) -> Operand<'a, f64> {
        Operand { data: m.as_slice(), rs: 1, cs: m.cols() }
    }
}

impl<'a> Operand<'a, f32> {
    fn normal32(m: &'a Mat32) -> Operand<'a, f32> {
        Operand { data: m.as_slice(), rs: m.cols(), cs: 1 }
    }
    fn transposed32(m: &'a Mat32) -> Operand<'a, f32> {
        Operand { data: m.as_slice(), rs: 1, cs: m.cols() }
    }
}

/// A GEMM execution context: either purely sequential (`pool == None`) or
/// row-panel parallel over a fixed [`ThreadPool`]. Cloning shares the pool.
///
/// Determinism: results are bit-identical for every thread count at a fixed
/// ([`GemmBlocking`], [`MicroKernel`]) pair (see the module docs); the
/// engine exists so callers can *choose* their parallelism and kernel, not
/// so they can get different answers.
#[derive(Clone, Default)]
pub struct GemmEngine {
    pool: Option<Arc<ThreadPool>>,
    /// Engine-local blocking override; `None` reads [`global_blocking`] at
    /// each call.
    blocking: Option<GemmBlocking>,
    /// Engine-local microkernel override; `None` reads [`global_kernel`] at
    /// each call.
    kernel: Option<MicroKernel>,
}

impl GemmEngine {
    /// Sequential engine (no pool, no dispatch overhead).
    pub fn sequential() -> GemmEngine {
        GemmEngine::default()
    }

    /// Engine with its own pool of `threads` workers (1 → sequential).
    pub fn with_threads(threads: usize) -> GemmEngine {
        if threads <= 1 {
            GemmEngine::sequential()
        } else {
            GemmEngine {
                pool: Some(Arc::new(ThreadPool::new(threads))),
                ..GemmEngine::default()
            }
        }
    }

    /// Pin this engine to fixed cache-block sizes instead of the global
    /// knob (benchmark sweeps, tests isolating themselves from the global).
    pub fn with_blocking(mut self, blk: GemmBlocking) -> GemmEngine {
        self.blocking = Some(blk.clamped());
        self
    }

    /// Pin this engine to a fixed microkernel instead of the global knob —
    /// the forced-selection hook the per-kernel conformance suite and the
    /// `perf_gemm` ablation run on.
    ///
    /// # Panics
    ///
    /// If `kern` is not available on this host; iterate
    /// [`MicroKernel::available`] to stay portable.
    pub fn with_kernel(mut self, kern: MicroKernel) -> GemmEngine {
        assert!(
            kern.is_available(),
            "gemm kernel '{}' is not available on this host",
            kern.name()
        );
        self.kernel = Some(kern);
        self
    }

    /// Worker count (1 for the sequential engine).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.size()).unwrap_or(1)
    }

    /// The blocking this engine's kernels run with.
    pub fn blocking(&self) -> GemmBlocking {
        self.blocking.unwrap_or_else(global_blocking)
    }

    /// The microkernel this engine's blocked path dispatches to.
    pub fn kernel(&self) -> MicroKernel {
        self.kernel.unwrap_or_else(global_kernel)
    }

    /// `C = A·B` into a caller-owned buffer (reshaped in place).
    pub fn matmul_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.rows(), "matmul: {:?} x {:?}", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::normal(b), c.as_mut_slice(), m, n, k, false);
    }

    /// `C = Aᵀ·B` into `c`. The packing stage reads A column-major, so no
    /// transpose is materialised (and no workspace is needed).
    pub fn matmul_at_b_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.rows(), b.rows(), "matmul_at_b: {:?}ᵀ x {:?}", a.shape(), b.shape());
        let (k, m) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::transposed(a), Operand::normal(b), c.as_mut_slice(), m, n, k, false);
    }

    /// `C = A·Bᵀ` into `c` (B read column-major by the packer — no
    /// transpose, no workspace).
    pub fn matmul_a_bt_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.cols(), "matmul_a_bt: {:?} x {:?}ᵀ", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.rows();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::transposed(b), c.as_mut_slice(), m, n, k, false);
    }

    /// Symmetric rank-k `C = AᵀA` into `c`: the blocked kernel restricted to
    /// upper-triangle micro-tiles (≈ n²k flops), mirrored afterwards —
    /// exactly symmetric by construction.
    pub fn syrk_at_a_into(&self, c: &mut Mat, a: &Mat) {
        let (k, n) = a.shape();
        GemmCounter::record_syrk(n, k);
        c.reset(n, n);
        c.fill_with(0.0);
        self.dispatch(Operand::transposed(a), Operand::normal(a), c.as_mut_slice(), n, n, k, true);
        mirror_upper(c);
    }

    /// Symmetric rank-k `C = A·Aᵀ` into `c` (same upper-triangle scheme).
    pub fn syrk_a_at_into(&self, c: &mut Mat, a: &Mat) {
        let (m, k) = a.shape();
        GemmCounter::record_syrk(m, k);
        c.reset(m, m);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::transposed(a), c.as_mut_slice(), m, m, k, true);
        mirror_upper(c);
    }

    /// `C = A·B` forced through the general blocked path, skipping the
    /// skinny routing. **§Perf ablation only** — this is the baseline the
    /// `perf_gemm` skinny rows compare against; it is never faster than
    /// [`GemmEngine::matmul_into`].
    pub fn matmul_blocked_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.rows(), "matmul: {:?} x {:?}", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        parallel::row_panels(
            self.pool.as_deref(),
            Operand::normal(a),
            Operand::normal(b),
            c.as_mut_slice(),
            m,
            n,
            k,
            self.blocking().clamped(),
            self.kernel(),
            false,
        );
    }

    /// Allocating convenience forms of the `*_into` calls.
    pub fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_into(&mut c, a, b);
        c
    }
    pub fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_at_b_into(&mut c, a, b);
        c
    }
    pub fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_a_bt_into(&mut c, a, b);
        c
    }
    pub fn syrk_at_a(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_at_a_into(&mut c, a);
        c
    }
    pub fn syrk_a_at(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_a_at_into(&mut c, a);
        c
    }

    // ── f32 entry points (mixed-precision iterate path) ──

    /// `C = A·B` over f32 operands into a caller-owned [`Mat32`] (reshaped
    /// in place). Same routing, counters and determinism contract as
    /// [`GemmEngine::matmul_into`]; f32 accumulation throughout.
    pub fn matmul_f32_into(&self, c: &mut Mat32, a: &Mat32, b: &Mat32) {
        assert_eq!(a.cols(), b.rows(), "matmul_f32: {:?} x {:?}", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch32(
            Operand::normal32(a),
            Operand::normal32(b),
            c.as_mut_slice(),
            m,
            n,
            k,
            false,
        );
    }

    /// Symmetric rank-k `C = AᵀA` over f32 into `c` (upper-triangle kernel
    /// plus mirror — exactly symmetric by construction, like the f64 path).
    pub fn syrk_at_a_f32_into(&self, c: &mut Mat32, a: &Mat32) {
        let (k, n) = a.shape();
        GemmCounter::record_syrk(n, k);
        c.reset(n, n);
        c.fill_with(0.0);
        self.dispatch32(
            Operand::transposed32(a),
            Operand::normal32(a),
            c.as_mut_slice(),
            n,
            n,
            k,
            true,
        );
        mirror_upper32(c);
    }

    /// Allocating convenience forms of the f32 `*_into` calls.
    pub fn matmul_f32(&self, a: &Mat32, b: &Mat32) -> Mat32 {
        let mut c = Mat32::zeros(0, 0);
        self.matmul_f32_into(&mut c, a, b);
        c
    }
    pub fn syrk_at_a_f32(&self, a: &Mat32) -> Mat32 {
        let mut c = Mat32::zeros(0, 0);
        self.syrk_at_a_f32_into(&mut c, a);
        c
    }

    /// `C += op(A)·op(B)`: resolve the kernel once, route skinny shapes to
    /// the streaming paths, and send everything else to the blocked path
    /// (row-panel parallel when a pool is attached). See "Dispatch rules"
    /// in the module docs; routing depends only on shape and operand form,
    /// never on pool size, so the thread count cannot change any output
    /// bit. With `upper_only`, micro-tiles strictly below the diagonal are
    /// skipped (the caller mirrors the upper triangle afterwards).
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        a: Operand<'_>,
        b: Operand<'_>,
        c: &mut [f64],
        m: usize,
        n: usize,
        k: usize,
        upper_only: bool,
    ) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        // Skinny routing: pack only the small operand, stream the dominant
        // one. SYRK stays on the blocked path (its triangle filter lives
        // there); a skinny SYRK output is tiny either way. thin-B gets the
        // pool (a tall GEMV splits its rows); thin-A has ≤ MR rows, below
        // any useful split.
        if !upper_only {
            if m <= MR {
                return skinny::thin_a(a, b, c, m, n, k);
            }
            if n <= NR {
                return skinny::thin_b(self.pool.as_deref(), a, b, c, m, n, k);
            }
        }
        // Snapshot blocking + kernel once so every panel of this call agrees.
        parallel::row_panels(
            self.pool.as_deref(),
            a,
            b,
            c,
            m,
            n,
            k,
            self.blocking().clamped(),
            self.kernel(),
            upper_only,
        );
    }

    /// f32 twin of [`GemmEngine::dispatch`]: identical routing rules against
    /// the f32 tile grid (`MR32`/`NR32`), blocking clamped to that grid.
    #[allow(clippy::too_many_arguments)]
    fn dispatch32(
        &self,
        a: Operand<'_, f32>,
        b: Operand<'_, f32>,
        c: &mut [f32],
        m: usize,
        n: usize,
        k: usize,
        upper_only: bool,
    ) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        if !upper_only {
            if m <= MR32 {
                return skinny::thin_a32(a, b, c, m, n, k);
            }
            if n <= NR32 {
                return skinny::thin_b32(self.pool.as_deref(), a, b, c, m, n, k);
            }
        }
        parallel::row_panels32(
            self.pool.as_deref(),
            a,
            b,
            c,
            m,
            n,
            k,
            self.blocking().clamped32(),
            self.kernel(),
            upper_only,
        );
    }
}

// ───────────────────────── global engine ──────────────────────────

/// The process-global engine behind the free functions below. Defaults to
/// sequential; [`set_global_threads`] (driven by `--threads` /
/// `service.gemm_threads`) installs a shared pool.
static GLOBAL_ENGINE: Mutex<Option<GemmEngine>> = Mutex::new(None);

/// Snapshot of the process-global engine. Engines grab this once per run and
/// reuse it, so the mutex is off the per-GEMM path.
pub fn global_engine() -> GemmEngine {
    lock_or_recover(&GLOBAL_ENGINE).clone().unwrap_or_default()
}

/// Install a process-global GEMM pool of `threads` workers (1 tears the pool
/// down). Safe to call at any time: results are bit-identical for every
/// thread count, so in-flight callers at the old size stay consistent.
pub fn set_global_threads(threads: usize) {
    let mut g = lock_or_recover(&GLOBAL_ENGINE);
    let current = g.as_ref().map(|e| e.threads()).unwrap_or(1);
    if current != threads.max(1) {
        *g = Some(GemmEngine::with_threads(threads));
    }
}

/// Current global GEMM thread count.
pub fn global_threads() -> usize {
    lock_or_recover(&GLOBAL_ENGINE).as_ref().map(|e| e.threads()).unwrap_or(1)
}

// ─────────────── free-function API (global engine) ───────────────

/// `C = A · B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul(a, b)
}

/// `C = Aᵀ · B` (A packed column-major — no transpose materialised).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_at_b(a, b)
}

/// `C = A · Bᵀ` (B packed column-major — no transpose materialised).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_a_bt(a, b)
}

/// Symmetric rank-k: `C = Aᵀ A` (exactly symmetric by construction).
pub fn syrk_at_a(a: &Mat) -> Mat {
    global_engine().syrk_at_a(a)
}

/// Symmetric rank-k: `C = A Aᵀ`.
pub fn syrk_a_at(a: &Mat) -> Mat {
    global_engine().syrk_a_at(a)
}

/// `C = A·B` into a reused buffer, on the global engine.
pub fn matmul_into(c: &mut Mat, a: &Mat, b: &Mat) {
    global_engine().matmul_into(c, a, b)
}

/// `C = AᵀA` into a reused buffer, on the global engine.
pub fn syrk_at_a_into(c: &mut Mat, a: &Mat) {
    global_engine().syrk_at_a_into(c, a)
}

/// Copy the upper triangle into the lower one (exact symmetry).
fn mirror_upper(c: &mut Mat) {
    let n = c.rows();
    for i in 1..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

/// f32 twin of [`mirror_upper`].
fn mirror_upper32(c: &mut Mat32) {
    let n = c.rows();
    for i in 1..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: &Mat, b: &Mat, tol: f64) -> bool {
        a.shape() == b.shape() && a.sub(b).max_abs() < tol
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 13, 9), (64, 64, 64), (65, 130, 33)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 20, 20, 1.0);
        assert!(close(&matmul(&a, &Mat::eye(20)), &a, 1e-12));
        assert!(close(&matmul(&Mat::eye(20), &a), &a, 1e-12));
    }

    #[test]
    fn at_b_and_a_bt_match() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 12, 7, 1.0);
        let b = Mat::gaussian(&mut rng, 12, 9, 1.0);
        let want = matmul_naive(&a.transpose(), &b);
        assert!(close(&matmul_at_b(&a, &b), &want, 1e-10));

        let c = Mat::gaussian(&mut rng, 9, 7, 1.0);
        let want2 = matmul_naive(&a, &c.transpose());
        assert!(close(&matmul_a_bt(&a, &c), &want2, 1e-10));
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::gaussian(&mut rng, 15, 8, 1.0);
        let want = matmul_naive(&a.transpose(), &a);
        let got = syrk_at_a(&a);
        assert!(close(&got, &want, 1e-10));
        assert_eq!(got.symmetry_defect(), 0.0);

        let want2 = matmul_naive(&a, &a.transpose());
        let got2 = syrk_a_at(&a);
        assert!(close(&got2, &want2, 1e-10));
        assert_eq!(got2.symmetry_defect(), 0.0);
    }

    #[test]
    fn every_available_kernel_matches_naive() {
        // Forced selection through with_kernel: all paths, per kernel.
        // Cross-kernel bit equality is NOT asserted (FMA vs separate
        // rounding) — tolerance only, per the documented contract.
        let mut rng = Rng::seed_from(11);
        for kern in MicroKernel::available() {
            let eng = GemmEngine::sequential().with_kernel(kern);
            assert_eq!(eng.kernel(), kern);
            for &(m, k, n) in &[(9, 12, 10), (33, 17, 29), (64, 64, 64)] {
                let a = Mat::gaussian(&mut rng, m, k, 1.0);
                let b = Mat::gaussian(&mut rng, k, n, 1.0);
                assert!(
                    close(&eng.matmul(&a, &b), &matmul_naive(&a, &b), 1e-10),
                    "{} {m}x{k}x{n}",
                    kern.name()
                );
                let s = eng.syrk_at_a(&a);
                assert!(close(&s, &matmul_naive(&a.transpose(), &a), 1e-10), "{}", kern.name());
                assert_eq!(s.symmetry_defect(), 0.0);
            }
        }
    }

    #[test]
    fn skinny_paths_match_naive_all_forms() {
        // m ≤ MR routes thin-A, n ≤ NR routes thin-B, including the m == 1
        // and n == 1 packed-GEMV cases and the transposed operand forms
        // (which exercise the strided streaming branches).
        let mut rng = Rng::seed_from(12);
        let eng = GemmEngine::sequential();
        for &(m, k, n) in &[
            (1, 40, 1),
            (1, 33, 50),
            (50, 33, 1),
            (8, 64, 64), // the sketch shape: p×n · n×n
            (3, 17, 100),
            (100, 17, 3),
            (7, 9, 4),
        ] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(&eng.matmul(&a, &b), &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
            // Aᵀ·B with A stored k-major (strided A reads).
            let at = Mat::gaussian(&mut rng, k, m, 1.0);
            assert!(
                close(&eng.matmul_at_b(&at, &b), &matmul_naive(&at.transpose(), &b), 1e-10),
                "at_b {m}x{k}x{n}"
            );
            // A·Bᵀ with B stored n-major (strided B reads).
            let bt = Mat::gaussian(&mut rng, n, k, 1.0);
            assert!(
                close(&eng.matmul_a_bt(&a, &bt), &matmul_naive(&a, &bt.transpose()), 1e-10),
                "a_bt {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn skinny_path_ignores_blocking() {
        // Regression for the GemmBlocking::clamped interaction: skinny
        // products route before any blocking applies, so their results are
        // bit-identical across arbitrary blockings (the blocked path would
        // regroup the reduction per KC block and differ in low bits).
        let mut rng = Rng::seed_from(13);
        let blks = [
            GemmBlocking::default(),
            GemmBlocking { mc: 8, kc: 5, nc: 7 },
            GemmBlocking { mc: 1, kc: 1, nc: 1 }, // clamps to (MR, 1, NR)
        ];
        for &(m, k, n) in &[(1, 300, 1), (8, 257, 64), (40, 257, 1), (1, 64, 33)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let base = GemmEngine::sequential().with_blocking(blks[0]).matmul(&a, &b);
            assert!(close(&base, &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
            for blk in &blks[1..] {
                let got = GemmEngine::sequential().with_blocking(*blk).matmul(&a, &b);
                assert_eq!(
                    base.as_slice(),
                    got.as_slice(),
                    "skinny {m}x{k}x{n} depends on blocking {}",
                    blk.display()
                );
            }
        }
    }

    #[test]
    fn blocked_ablation_entry_matches_routed_path() {
        let mut rng = Rng::seed_from(14);
        let eng = GemmEngine::sequential();
        // Skinny shape: routed path uses thin-A, forced path uses blocks —
        // equal to fp tolerance, not necessarily bitwise.
        let a = Mat::gaussian(&mut rng, 8, 120, 1.0);
        let b = Mat::gaussian(&mut rng, 120, 60, 1.0);
        let mut c = Mat::zeros(0, 0);
        eng.matmul_blocked_into(&mut c, &a, &b);
        assert!(close(&c, &matmul_naive(&a, &b), 1e-10));
        // Non-skinny shape: both entries run the identical blocked path.
        let a2 = Mat::gaussian(&mut rng, 40, 30, 1.0);
        let b2 = Mat::gaussian(&mut rng, 30, 20, 1.0);
        eng.matmul_blocked_into(&mut c, &a2, &b2);
        assert_eq!(c.as_slice(), eng.matmul(&a2, &b2).as_slice());
    }

    #[test]
    fn gemm_counter_increments() {
        let before = GemmCounter::calls();
        let mut rng = Rng::seed_from(5);
        let a = Mat::gaussian(&mut rng, 4, 4, 1.0);
        let _ = matmul(&a, &a);
        assert!(GemmCounter::calls() > before);
        assert!(GemmCounter::flops() > 0);
    }

    #[test]
    fn into_calls_record_once_and_syrk_counts_half() {
        let mut rng = Rng::seed_from(6);
        let a = Mat::gaussian(&mut rng, 6, 4, 1.0);
        let b = Mat::gaussian(&mut rng, 4, 3, 1.0);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);

        let scope = GemmScope::begin();
        eng.matmul_into(&mut c, &a, &b);
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 0);
        assert_eq!(scope.flops(), 2 * 6 * 3 * 4);

        let scope = GemmScope::begin();
        eng.syrk_at_a_into(&mut c, &a); // AᵀA: n=4, k=6 → n²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 1);
        assert_eq!(scope.flops(), 4 * 4 * 6);

        let scope = GemmScope::begin();
        eng.syrk_a_at_into(&mut c, &a); // AAᵀ: m=6, k=4 → m²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 1);
        assert_eq!(scope.flops(), 6 * 6 * 4);
    }

    #[test]
    fn into_reuses_buffers_across_shapes() {
        let mut rng = Rng::seed_from(7);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);
        for &(m, k, n) in &[(5, 7, 3), (2, 2, 2), (9, 4, 11)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            eng.matmul_into(&mut c, &a, &b);
            assert!(close(&c, &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_engine_bit_identical_to_sequential() {
        let mut rng = Rng::seed_from(8);
        // Per available kernel: sizes straddling the parallel threshold and
        // ragged splits must be bit-identical across pool sizes.
        for kern in MicroKernel::available() {
            let seq = GemmEngine::sequential().with_kernel(kern);
            let par = GemmEngine::with_threads(4).with_kernel(kern);
            for &(m, k, n) in &[(1, 3, 2), (16, 16, 16), (33, 17, 29), (70, 40, 55)] {
                let a = Mat::gaussian(&mut rng, m, k, 1.0);
                let b = Mat::gaussian(&mut rng, k, n, 1.0);
                let c_seq = seq.matmul(&a, &b);
                let c_par = par.matmul(&a, &b);
                assert_eq!(c_seq, c_par, "{} matmul {m}x{k}x{n} not bit-identical", kern.name());
                let s_seq = seq.syrk_at_a(&a);
                let s_par = par.syrk_at_a(&a);
                assert_eq!(s_seq, s_par, "{} syrk {m}x{k} not bit-identical", kern.name());
            }
        }
    }

    #[test]
    fn custom_blocking_stays_correct() {
        // Tiny blocks force every edge path (ragged tiles, many KC/NC
        // blocks) without touching the process-global knob.
        let mut rng = Rng::seed_from(9);
        let blk = GemmBlocking { mc: 8, kc: 5, nc: 7 };
        let eng = GemmEngine::sequential().with_blocking(blk);
        assert_eq!(eng.blocking(), blk.clamped());
        for &(m, k, n) in &[(1, 1, 1), (13, 11, 9), (40, 23, 31)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(
                close(&eng.matmul(&a, &b), &matmul_naive(&a, &b), 1e-10),
                "blocked {m}x{k}x{n}"
            );
            let sa = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(
                &eng.syrk_at_a(&sa),
                &matmul_naive(&sa.transpose(), &sa),
                1e-10
            ));
        }
        // And a parallel engine at the same blocking stays bit-identical.
        let par = GemmEngine::with_threads(3).with_blocking(blk);
        let a = Mat::gaussian(&mut rng, 70, 19, 1.0);
        let b = Mat::gaussian(&mut rng, 19, 26, 1.0);
        assert_eq!(eng.matmul(&a, &b), par.matmul(&a, &b));
    }

    #[test]
    fn blocking_parse_roundtrip() {
        let b = GemmBlocking::parse("64x128x256").unwrap();
        assert_eq!(b, GemmBlocking { mc: 64, kc: 128, nc: 256 });
        assert_eq!(GemmBlocking::parse(&b.display()).unwrap(), b);
        assert_eq!(
            GemmBlocking::parse("64,128,256").unwrap(),
            GemmBlocking { mc: 64, kc: 128, nc: 256 }
        );
        assert!(GemmBlocking::parse("64x128").is_err());
        assert!(GemmBlocking::parse("64x0x256").is_err());
        assert!(GemmBlocking::parse("axbxc").is_err());
    }

    #[test]
    fn global_blocking_roundtrip() {
        // Only ever set the default value here: the global knob is
        // bit-level observable, and unit tests run concurrently.
        set_global_blocking(GemmBlocking::default());
        assert_eq!(global_blocking(), GemmBlocking::default());
    }

    #[test]
    fn global_kernel_resolves_to_an_available_kernel() {
        // Never install a non-default global here (concurrent tests would
        // observe it); just check the read path. Under PALLAS_GEMM_KERNEL
        // the resolved kernel may differ from detect() — by design — but it
        // must always be runnable on this host.
        assert!(global_kernel().is_available());
        assert_eq!(GemmEngine::sequential().kernel(), global_kernel());
    }

    #[test]
    fn broadcast_kernel_matches_packed() {
        let mut rng = Rng::seed_from(10);
        for &(m, k, n) in &[(5, 9, 3), (33, 20, 41)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let mut c = Mat::zeros(m, n);
            gemm_broadcast(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
            assert!(close(&c, &matmul(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn workspace_recycles() {
        let mut ws = Workspace::new();
        let m1 = ws.take(4, 4);
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1);
        ws.put(m1);
        assert_eq!(ws.len(), 1);
        let m2 = ws.take(2, 6); // reshaped reuse: 12 elems fit in capacity 16
        assert_eq!(m2.shape(), (2, 6));
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1, "fitting reuse must not count as alloc");
    }

    #[test]
    fn workspace_prefers_fitting_buffer() {
        let mut ws = Workspace::new();
        let small = ws.take(2, 2);
        let big = ws.take(8, 8);
        ws.put(small);
        ws.put(big);
        assert_eq!(ws.allocations(), 2);
        // A 6x6 request skips the 2x2 buffer and reuses the 8x8 one.
        let m = ws.take(6, 6);
        assert_eq!(m.shape(), (6, 6));
        assert_eq!(ws.allocations(), 2);
        assert_eq!(ws.len(), 1);
        // Nothing fits 10x10: counts as an allocation (grown in place).
        let g = ws.take(10, 10);
        assert_eq!(g.shape(), (10, 10));
        assert_eq!(ws.allocations(), 3);
    }

    #[test]
    fn workspace_best_fit_avoids_cross_size_thrash() {
        // A pool holding mixed sizes (engine n×n buffers next to sketch p×n
        // panels) must serve each request from the matching size class —
        // first-fit would hand the big buffer to the small request and then
        // grow the small buffer for the big one, allocating every cycle.
        let mut ws = Workspace::new();
        let big = ws.take(16, 16);
        let small = ws.take(2, 2);
        ws.put(big); // free list order: [big, small]
        ws.put(small);
        assert_eq!(ws.allocations(), 2);
        for _ in 0..3 {
            let s = ws.take(2, 2);
            assert!(s.capacity() < 16 * 16, "small take must not consume the big buffer");
            let b = ws.take(16, 16);
            ws.put(s);
            ws.put(b);
        }
        assert_eq!(ws.allocations(), 2, "steady mixed-size cycling must not allocate");
    }

    fn g32(rng: &mut Rng, m: usize, n: usize) -> Mat32 {
        Mat32::from_f64(&Mat::gaussian(rng, m, n, 1.0))
    }

    fn close32(a: &Mat32, b: &Mat32, tol: f64) -> bool {
        a.shape() == b.shape()
            && a.as_slice()
                .iter()
                .zip(b.as_slice())
                .all(|(x, y)| ((x - y).abs() as f64) < tol)
    }

    #[test]
    fn f32_matmul_matches_naive32_every_kernel() {
        // The dtype conformance axis at unit grain: blocked, thin-A (m ≤
        // MR32), thin-B (n ≤ NR32) and GEMV shapes per available kernel,
        // vs the f32 naive reference at f32-round-off tolerance.
        let mut rng = Rng::seed_from(21);
        for kern in MicroKernel::available() {
            let eng = GemmEngine::sequential().with_kernel(kern);
            for &(m, k, n) in &[
                (1, 40, 1),
                (8, 64, 64), // sketch shape → thin-A32
                (50, 33, 1), // GEMV → thin-B32
                (3, 17, 100),
                (33, 17, 29),
                (64, 64, 64),
            ] {
                let a = g32(&mut rng, m, k);
                let b = g32(&mut rng, k, n);
                let want = matmul_naive32(&a, &b);
                assert!(
                    close32(&eng.matmul_f32(&a, &b), &want, 1e-3),
                    "{} f32 {m}x{k}x{n}",
                    kern.name()
                );
            }
        }
    }

    #[test]
    fn f32_syrk_matches_and_is_exactly_symmetric() {
        let mut rng = Rng::seed_from(22);
        for kern in MicroKernel::available() {
            let eng = GemmEngine::sequential().with_kernel(kern);
            for &(k, n) in &[(15, 8), (40, 33)] {
                let a = g32(&mut rng, k, n);
                let got = eng.syrk_at_a_f32(&a);
                let at = a.to_f64().transpose();
                let want = Mat32::from_f64(&matmul_naive(&at, &a.to_f64()));
                assert!(close32(&got, &want, 1e-3), "{} syrk_f32 {k}x{n}", kern.name());
                for i in 0..n {
                    for j in 0..i {
                        assert_eq!(got[(i, j)], got[(j, i)], "f32 syrk not exactly symmetric");
                    }
                }
            }
        }
    }

    #[test]
    fn f32_parallel_engine_bit_identical_to_sequential() {
        // The determinism contract holds per dtype: for a fixed kernel, the
        // f32 path is bit-identical across pool sizes too.
        let mut rng = Rng::seed_from(23);
        for kern in MicroKernel::available() {
            let seq = GemmEngine::sequential().with_kernel(kern);
            let par = GemmEngine::with_threads(4).with_kernel(kern);
            for &(m, k, n) in &[(1, 3, 2), (16, 16, 16), (33, 17, 29), (70, 40, 55)] {
                let a = g32(&mut rng, m, k);
                let b = g32(&mut rng, k, n);
                assert!(
                    seq.matmul_f32(&a, &b) == par.matmul_f32(&a, &b),
                    "{} f32 matmul {m}x{k}x{n} not bit-identical",
                    kern.name()
                );
                assert!(
                    seq.syrk_at_a_f32(&a) == par.syrk_at_a_f32(&a),
                    "{} f32 syrk {m}x{k} not bit-identical",
                    kern.name()
                );
            }
        }
    }

    #[test]
    fn workspace_f32_side_recycles_and_shares_alloc_counter() {
        let mut ws = Workspace::new();
        let m1 = ws.take_f32(4, 4);
        assert_eq!(ws.allocations(), 1);
        ws.put_f32(m1);
        let m2 = ws.take_f32(2, 6); // 12 elems fit in capacity 16
        assert_eq!(m2.shape(), (2, 6));
        assert_eq!(ws.allocations(), 1, "fitting f32 reuse must not count as alloc");
        ws.put_f32(m2);
        // The dtypes never trade buffers: an f64 take after an f32 put must
        // allocate (and vice versa), on the one shared counter.
        let d = ws.take(2, 2);
        assert_eq!(ws.allocations(), 2);
        ws.put(d);
        let f = ws.take_f32(4, 4);
        assert_eq!(ws.allocations(), 2, "f32 take must reuse the f32 buffer");
        ws.put_f32(f);
    }

    #[test]
    fn global_threads_roundtrip() {
        // Default is sequential; setting 1 keeps it sequential. (Setting >1
        // here would leak a pool into unrelated unit tests' timing, so the
        // parallel paths are covered by the local-engine tests above.)
        set_global_threads(1);
        assert_eq!(global_threads(), 1);
        assert_eq!(global_engine().threads(), 1);
    }
}
