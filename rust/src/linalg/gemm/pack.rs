//! Panel packing: copy cache blocks of the (possibly strided) operands into
//! contiguous, k-major, zero-padded panels the microkernels stream.
//!
//! Packing reads the source through an [`Operand`]'s (row, col) strides, so
//! `A`, `Aᵀ`, `B` and `Bᵀ` are all served from their original buffers — no
//! transpose is ever materialised. Ragged panel tails are zero-padded to
//! full `MR`/`NR` width: the microkernels always run a full tile, the padded
//! lanes contribute exact zeros, and the store-back loops simply clip them.
//! This zero-padding is also a load-bearing **safety** property for the
//! SIMD kernels (see [`super::kernel`]): it guarantees every panel holds
//! `kb·MR` / `kb·NR` readable elements.

use super::kernel::{MR, MR32, NR, NR32};
use super::Operand;

/// Pack rows `i0..i1`, cols `k0..k1` of `a` into MR-row panels, k-major:
/// panel `p` holds rows `i0+p·MR ..`, stored as `buf[p·kb·MR + t·MR + r]`
/// for k index `t` (0-based within the block) and panel row `r`. Rows past
/// `i1` are zero-padded so the microkernel always runs a full tile.
pub(super) fn pack_a(buf: &mut [f64], a: Operand<'_>, i0: usize, i1: usize, k0: usize, k1: usize) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut ti = i0;
    while ti < i1 {
        let h = MR.min(i1 - ti);
        for t in 0..kb {
            let dst = &mut buf[off + t * MR..off + t * MR + MR];
            for r in 0..MR {
                dst[r] = if r < h { a.at(ti + r, k0 + t) } else { 0.0 };
            }
        }
        off += kb * MR;
        ti += MR;
    }
}

/// Pack rows `k0..k1`, cols `j0..j1` of `b` into NR-column panels, k-major:
/// panel `p` holds cols `j0+p·NR ..`, stored as `buf[p·kb·NR + t·NR + j]`.
/// Columns past `j1` are zero-padded.
pub(super) fn pack_b(buf: &mut [f64], b: Operand<'_>, k0: usize, k1: usize, j0: usize, j1: usize) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut js = j0;
    while js < j1 {
        let w = NR.min(j1 - js);
        for t in 0..kb {
            let dst = &mut buf[off + t * NR..off + t * NR + NR];
            for j in 0..NR {
                dst[j] = if j < w { b.at(k0 + t, js + j) } else { 0.0 };
            }
        }
        off += kb * NR;
        js += NR;
    }
}

/// f32 twin of [`pack_a`] over `MR32`-row panels. Kept as a duplicate
/// rather than a generic: the panel widths differ per dtype (`MR` vs
/// `MR32`), and the two bodies are small enough that a const-generic
/// indirection would cost more clarity than it saves.
pub(super) fn pack_a32(
    buf: &mut [f32],
    a: Operand<'_, f32>,
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut ti = i0;
    while ti < i1 {
        let h = MR32.min(i1 - ti);
        for t in 0..kb {
            let dst = &mut buf[off + t * MR32..off + t * MR32 + MR32];
            for r in 0..MR32 {
                dst[r] = if r < h { a.at(ti + r, k0 + t) } else { 0.0 };
            }
        }
        off += kb * MR32;
        ti += MR32;
    }
}

/// f32 twin of [`pack_b`] over `NR32`-column panels.
pub(super) fn pack_b32(
    buf: &mut [f32],
    b: Operand<'_, f32>,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut js = j0;
    while js < j1 {
        let w = NR32.min(j1 - js);
        for t in 0..kb {
            let dst = &mut buf[off + t * NR32..off + t * NR32 + NR32];
            for j in 0..NR32 {
                dst[j] = if j < w { b.at(k0 + t, js + j) } else { 0.0 };
            }
        }
        off += kb * NR32;
        js += NR32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Mat, Mat32};
    use crate::rng::Rng;

    #[test]
    fn pack_a_is_k_major_and_zero_padded() {
        let mut rng = Rng::seed_from(1);
        let a = Mat::gaussian(&mut rng, 5, 3, 1.0); // 5 rows: one ragged panel
        let mut buf = vec![f64::NAN; 3 * MR];
        pack_a(&mut buf, Operand::normal(&a), 0, 5, 0, 3);
        for t in 0..3 {
            for r in 0..MR {
                let want = if r < 5 { a[(r, t)] } else { 0.0 };
                assert_eq!(buf[t * MR + r], want, "t={t} r={r}");
            }
        }
    }

    #[test]
    fn pack_a32_is_k_major_and_zero_padded() {
        let mut rng = Rng::seed_from(3);
        let a = Mat32::from_f64(&Mat::gaussian(&mut rng, 5, 3, 1.0)); // ragged panel
        let mut buf = vec![f32::NAN; 3 * MR32];
        pack_a32(&mut buf, Operand::normal32(&a), 0, 5, 0, 3);
        for t in 0..3 {
            for r in 0..MR32 {
                let want = if r < 5 { a[(r, t)] } else { 0.0 };
                assert_eq!(buf[t * MR32 + r], want, "t={t} r={r}");
            }
        }
    }

    #[test]
    fn pack_b32_zero_pads_ragged_columns() {
        let mut rng = Rng::seed_from(4);
        let b = Mat32::from_f64(&Mat::gaussian(&mut rng, 6, 3, 1.0)); // 3 < NR32 cols
        let mut buf = vec![f32::NAN; 6 * NR32];
        pack_b32(&mut buf, Operand::normal32(&b), 0, 6, 0, 3);
        for t in 0..6 {
            for j in 0..NR32 {
                let want = if j < 3 { b[(t, j)] } else { 0.0 };
                assert_eq!(buf[t * NR32 + j], want, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn pack_b_reads_transposed_operand_without_transpose() {
        let mut rng = Rng::seed_from(2);
        let b = Mat::gaussian(&mut rng, 3, 6, 1.0); // used as Bᵀ: 6x3
        let mut buf = vec![f64::NAN; 6 * NR];
        pack_b(&mut buf, Operand::transposed(&b), 0, 6, 0, 3);
        for t in 0..6 {
            for j in 0..NR {
                let want = if j < 3 { b[(j, t)] } else { 0.0 };
                assert_eq!(buf[t * NR + j], want, "t={t} j={j}");
            }
        }
    }
}
