//! Classical decompositions: Cholesky, LU (with partial pivoting) and
//! Householder QR. These back the DB-Newton engine (Cholesky-based inverse),
//! the eigen baseline (orthogonal iteration helpers) and the random-matrix
//! generators (Haar orthogonal via QR).

use super::Mat;
use crate::util::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
/// Fails on non-SPD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if !a.is_square() {
        return Err(Error::Shape(format!("cholesky: {:?} not square", a.shape())));
    }
    let n = a.rows();
    let mut l = Mat::zeros(n, n);
    for j in 0..n {
        let mut d = a[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(Error::Numerical(format!(
                "cholesky: pivot {d:.3e} at column {j} (matrix not SPD)"
            )));
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        for i in (j + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s / dj;
        }
    }
    Ok(l)
}

/// Solve `L y = b` (lower-triangular, forward substitution), in place into `b`.
pub fn forward_sub(l: &Mat, b: &mut [f64]) {
    let n = l.rows();
    assert_eq!(b.len(), n);
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * b[k];
        }
        b[i] = s / l[(i, i)];
    }
}

/// Solve `Lᵀ x = y` (backward substitution), in place.
pub fn backward_sub_t(l: &Mat, b: &mut [f64]) {
    let n = l.rows();
    assert_eq!(b.len(), n);
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * b[k];
        }
        b[i] = s / l[(i, i)];
    }
}

/// SPD inverse via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`.
/// This is the paper's recommended path for DB-Newton's `M_k⁻¹`.
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let l = cholesky(a)?;
    // Solve A X = I column by column (two triangular solves each).
    let mut inv = Mat::zeros(n, n);
    let mut col = vec![0.0; n];
    for j in 0..n {
        col.iter_mut().for_each(|x| *x = 0.0);
        col[j] = 1.0;
        forward_sub(&l, &mut col);
        backward_sub_t(&l, &mut col);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    // Enforce exact symmetry (removes O(eps) drift).
    inv.symmetrize();
    Ok(inv)
}

/// LU decomposition with partial pivoting. Returns (LU packed, perm, sign).
pub struct Lu {
    pub lu: Mat,
    pub perm: Vec<usize>,
    pub sign: f64,
}

pub fn lu_decompose(a: &Mat) -> Result<Lu> {
    if !a.is_square() {
        return Err(Error::Shape(format!("lu: {:?} not square", a.shape())));
    }
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut pmax = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < 1e-300 {
            return Err(Error::Numerical(format!("lu: singular at column {k}")));
        }
        if p != k {
            perm.swap(p, k);
            sign = -sign;
            for j in 0..n {
                let tmp = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = tmp;
            }
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / pivot;
            lu[(i, k)] = f;
            for j in (k + 1)..n {
                let v = lu[(k, j)];
                lu[(i, j)] -= f * v;
            }
        }
    }
    Ok(Lu { lu, perm, sign })
}

/// Solve `A x = b` given an LU factorisation.
pub fn lu_solve_factored(f: &Lu, b: &[f64]) -> Vec<f64> {
    let n = f.lu.rows();
    assert_eq!(b.len(), n);
    let mut x: Vec<f64> = f.perm.iter().map(|&p| b[p]).collect();
    // forward (unit lower)
    for i in 0..n {
        let mut s = x[i];
        for k in 0..i {
            s -= f.lu[(i, k)] * x[k];
        }
        x[i] = s;
    }
    // backward (upper)
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in (i + 1)..n {
            s -= f.lu[(i, k)] * x[k];
        }
        x[i] = s / f.lu[(i, i)];
    }
    x
}

/// Solve `A x = b`.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let f = lu_decompose(a)?;
    Ok(lu_solve_factored(&f, b))
}

/// General inverse via LU.
pub fn lu_inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let f = lu_decompose(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.iter_mut().for_each(|x| *x = 0.0);
        e[j] = 1.0;
        let col = lu_solve_factored(&f, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Ok(inv)
}

/// Householder QR: returns (Q [m x n, thin], R [n x n]) with A = Q R, m >= n.
pub fn qr_householder(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr: need m >= n, got {m}x{n}");
    let mut r = a.clone();
    // Store Householder vectors.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build v for column k.
        let mut norm_x = 0.0;
        for i in k..m {
            norm_x += r[(i, k)] * r[(i, k)];
        }
        norm_x = norm_x.sqrt();
        let mut v = vec![0.0; m - k];
        if norm_x < 1e-300 {
            vs.push(v);
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -norm_x } else { norm_x };
        for i in k..m {
            v[i - k] = r[(i, k)];
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..].
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * r[(i, j)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                r[(i, j)] -= f * v[i - k];
            }
        }
        vs.push(v);
    }
    // Accumulate thin Q by applying the reflectors to I's first n columns in
    // reverse order.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= f * v[i - k];
            }
        }
    }
    // Zero R's lower triangle (numerical noise) and truncate to n x n.
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }
    (q, rr)
}

/// In-place column orthonormalization: modified Gram–Schmidt with one
/// re-orthogonalization pass, rank-revealing. Columns that are (numerically)
/// dependent on earlier ones are dropped; kept columns are compacted to the
/// left, the tail is zeroed, and the kept count — the numerical rank — is
/// returned. This is the thin-QR step of the randomized range finder, where
/// only the orthonormal basis is wanted, never R.
pub fn orthonormalize_columns(a: &mut Mat) -> usize {
    let (m, n) = a.shape();
    let mut kept = 0;
    for j in 0..n {
        if kept != j {
            for i in 0..m {
                let v = a[(i, j)];
                a[(i, kept)] = v;
            }
        }
        let mut norm0 = 0.0;
        for i in 0..m {
            norm0 += a[(i, kept)] * a[(i, kept)];
        }
        let norm0 = norm0.sqrt();
        // Two MGS passes: the second mops up the O(eps·κ) residue the first
        // leaves against nearly-parallel earlier columns ("twice is enough").
        for _ in 0..2 {
            for k in 0..kept {
                let mut dot = 0.0;
                for i in 0..m {
                    dot += a[(i, k)] * a[(i, kept)];
                }
                for i in 0..m {
                    let v = a[(i, k)];
                    a[(i, kept)] -= dot * v;
                }
            }
        }
        let mut norm = 0.0;
        for i in 0..m {
            norm += a[(i, kept)] * a[(i, kept)];
        }
        let norm = norm.sqrt();
        if norm <= 1e-10 * norm0 || norm < 1e-300 {
            continue;
        }
        let inv = 1.0 / norm;
        for i in 0..m {
            a[(i, kept)] *= inv;
        }
        kept += 1;
    }
    for j in kept..n {
        for i in 0..m {
            a[(i, j)] = 0.0;
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b, syrk_at_a};
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let g = Mat::gaussian(rng, n + 4, n, 1.0);
        let mut a = syrk_at_a(&g);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::seed_from(1);
        let a = spd(&mut rng, 12);
        let l = cholesky(&a).unwrap();
        let llt = matmul(&l, &l.transpose());
        assert!(a.sub(&llt).max_abs() < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn cholesky_inverse_works() {
        let mut rng = Rng::seed_from(2);
        let a = spd(&mut rng, 10);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.sub(&Mat::eye(10)).max_abs() < 1e-8);
        assert_eq!(inv.symmetry_defect(), 0.0);
    }

    #[test]
    fn lu_solve_matches() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 9, 9, 1.0);
        let x_true: Vec<f64> = (0..9).map(|i| i as f64 - 4.0).collect();
        let b = a.matvec(&x_true);
        let x = lu_solve(&a, &b).unwrap();
        for i in 0..9 {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn lu_inverse_works() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::gaussian(&mut rng, 11, 11, 1.0);
        let inv = lu_inverse(&a).unwrap();
        assert!(matmul(&a, &inv).sub(&Mat::eye(11)).max_abs() < 1e-7);
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Mat::zeros(3, 3);
        assert!(lu_decompose(&a).is_err());
    }

    #[test]
    fn qr_reconstructs_and_orthogonal() {
        let mut rng = Rng::seed_from(5);
        for &(m, n) in &[(8, 8), (15, 6), (30, 30)] {
            let a = Mat::gaussian(&mut rng, m, n, 1.0);
            let (q, r) = qr_householder(&a);
            let qr = matmul(&q, &r);
            assert!(a.sub(&qr).max_abs() < 1e-9, "{m}x{n} reconstruct");
            let qtq = matmul_at_b(&q, &q);
            assert!(qtq.sub(&Mat::eye(n)).max_abs() < 1e-10, "{m}x{n} orthogonality");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn orthonormalize_full_rank_keeps_all_columns() {
        let mut rng = Rng::seed_from(7);
        let mut a = Mat::gaussian(&mut rng, 20, 6, 1.0);
        let r = orthonormalize_columns(&mut a);
        assert_eq!(r, 6);
        let g = matmul_at_b(&a, &a);
        assert!(g.sub(&Mat::eye(6)).max_abs() < 1e-12);
    }

    #[test]
    fn orthonormalize_reveals_rank_and_compacts() {
        let mut rng = Rng::seed_from(8);
        // 3 independent columns, then exact copies: rank 3.
        let b = Mat::gaussian(&mut rng, 16, 3, 1.0);
        let mut a = Mat::zeros(16, 6);
        for j in 0..6 {
            for i in 0..16 {
                a[(i, j)] = b[(i, j % 3)];
            }
        }
        let r = orthonormalize_columns(&mut a);
        assert_eq!(r, 3);
        // Kept block orthonormal, tail zeroed.
        for j in 0..3 {
            for jj in 0..3 {
                let mut dot = 0.0;
                for i in 0..16 {
                    dot += a[(i, j)] * a[(i, jj)];
                }
                let want = if j == jj { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-12, "({j},{jj}): {dot}");
            }
        }
        for j in 3..6 {
            for i in 0..16 {
                assert_eq!(a[(i, j)], 0.0);
            }
        }
        // Kept block spans the same space as b: b = Q(QᵀB).
        let q = {
            let mut q = Mat::zeros(16, 3);
            for j in 0..3 {
                for i in 0..16 {
                    q[(i, j)] = a[(i, j)];
                }
            }
            q
        };
        let proj = matmul(&q, &matmul_at_b(&q, &b));
        assert!(proj.sub(&b).max_abs() < 1e-10);
    }

    #[test]
    fn orthonormalize_zero_matrix_has_rank_zero() {
        let mut a = Mat::zeros(10, 4);
        assert_eq!(orthonormalize_columns(&mut a), 0);
        assert_eq!(a, Mat::zeros(10, 4));
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::seed_from(6);
        let a = spd(&mut rng, 7);
        let l = cholesky(&a).unwrap();
        let x_true: Vec<f64> = (0..7).map(|i| (i as f64).sin()).collect();
        // b = L x
        let mut b = vec![0.0; 7];
        for i in 0..7 {
            for k in 0..=i {
                b[i] += l[(i, k)] * x_true[k];
            }
        }
        forward_sub(&l, &mut b);
        for i in 0..7 {
            assert!((b[i] - x_true[i]).abs() < 1e-10);
        }
    }
}
