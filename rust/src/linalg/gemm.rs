//! Packed, cache-blocked, parallel GEMM and symmetric rank-k engine.
//!
//! This is the O(n³) hot path of every Newton–Schulz-like iteration. The
//! layer has four pieces:
//!
//! 1. **The kernel** — a BLIS-style **packed, cache-blocked** design:
//!    three blocking loops (NC columns of B × KC rows of B × MC rows of A)
//!    wrap an 8×4 register-tiled microkernel. Before the microkernel runs,
//!    the current A block is packed into MR(=8)-row panels and the current
//!    B block into NR(=4)-column panels, both laid out k-major and
//!    zero-padded to full tiles, so the innermost loop streams two
//!    contiguous buffers and performs 32 independent `acc += a·b` updates
//!    per k step — a dependence-free form LLVM auto-vectorises into FMAs.
//!    Packing reads the source through (row, col) strides, so the
//!    transposed products `AᵀB`, `ABᵀ` and both SYRKs are served by the
//!    same kernel **without materialising any transpose**.
//! 2. **The blocking knobs** — [`GemmBlocking`] holds the `(MC, KC, NC)`
//!    cache-block sizes (defaults 128×256×512: an MC×KC A block is 256 KiB
//!    ≈ L2, a KC×NC B block is 1 MiB ≈ L2/L3, an MR×KC A panel is 16 KiB
//!    ≈ half of L1). Tune per machine via
//!    [`set_global_blocking`] (`--gemm-block MCxKCxNC` on the CLI,
//!    `service.gemm_block` in TOML) or per engine via
//!    [`GemmEngine::with_blocking`]. Results are deterministic for a fixed
//!    blocking; changing KC or NC regroups the reduction and may change
//!    low-order bits (a startup-time knob, not a per-call one).
//! 3. **The engine** — [`GemmEngine`] partitions the rows of C into
//!    contiguous panels and runs the packed kernel on each panel over the
//!    crate's [`crate::threads::ThreadPool`] (via
//!    [`crate::threads::scoped`]). For any fixed output element, the
//!    accumulation order is `(NC block, KC block, k)` with one
//!    register-accumulated partial sum per KC block — independent of how
//!    the rows were partitioned — so results are **bit-identical for every
//!    pool size**. With `threads() == 1` (the default global engine) no
//!    pool is touched and the call degrades to the sequential kernel.
//!    SYRK runs the same kernel restricted to micro-tiles that touch the
//!    upper triangle (≈ half the flops) and mirrors the result, staying
//!    exactly symmetric by construction.
//! 4. **The workspace API** — `*_into` variants write into caller-owned
//!    output buffers (reshaped in place, allocation reused). [`Workspace`]
//!    is a small buffer pool for iteration temporaries; the A/B packing
//!    buffers are drawn from a per-thread [`Workspace`] of their own and
//!    reused across calls, so steady-state GEMM traffic performs **zero
//!    heap allocation** (the iteration engines' ping-pong buffers are
//!    likewise pooled, asserted by the tier-1/matfn allocation tests).
//!
//! The seed's broadcast-FMA kernel is kept as [`gemm_broadcast`]: it is the
//! §Perf ablation baseline (`perf_gemm` reports packed-vs-broadcast
//! speedups) and a second independent implementation the conformance suite
//! can cross-check against, next to [`matmul_naive`].
//!
//! GEMM-call counting: the PRISM paper reports costs in units of GEMMs; the
//! engines count their invocations through [`GemmCounter`]. Counts are kept
//! both process-globally and per-thread; [`GemmScope`] reads the per-thread
//! counters so concurrent runs (service workers, parallel tests) never see
//! each other's calls. SYRK records its true n²k flop count — the mirrored
//! half is a copy, not recomputation — and is additionally tallied under
//! [`GemmCounter::syrk_calls`] so cost models can separate the two shapes.

use super::Mat;
use crate::threads::{scoped, ThreadPool};
use crate::util::{Error, Result};
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Process-wide GEMM counters (cheap relaxed atomics) plus thread-local
/// shadows for race-free per-run accounting.
static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_FLOPS: AtomicU64 = AtomicU64::new(0);
static SYRK_CALLS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL_CALLS: Cell<u64> = const { Cell::new(0) };
    static TL_FLOPS: Cell<u64> = const { Cell::new(0) };
    static TL_SYRK: Cell<u64> = const { Cell::new(0) };
}

pub struct GemmCounter;

impl GemmCounter {
    /// Process-wide call count (all threads, GEMM + SYRK).
    pub fn calls() -> u64 {
        GEMM_CALLS.load(Ordering::Relaxed)
    }
    /// Process-wide flop count (all threads).
    pub fn flops() -> u64 {
        GEMM_FLOPS.load(Ordering::Relaxed)
    }
    /// Process-wide SYRK call count (a subset of [`GemmCounter::calls`]).
    pub fn syrk_calls() -> u64 {
        SYRK_CALLS.load(Ordering::Relaxed)
    }
    fn add(calls: u64, flops: u64, syrk: u64) {
        GEMM_CALLS.fetch_add(calls, Ordering::Relaxed);
        GEMM_FLOPS.fetch_add(flops, Ordering::Relaxed);
        if syrk > 0 {
            SYRK_CALLS.fetch_add(syrk, Ordering::Relaxed);
            TL_SYRK.with(|c| c.set(c.get() + syrk));
        }
        TL_CALLS.with(|c| c.set(c.get() + calls));
        TL_FLOPS.with(|c| c.set(c.get() + flops));
    }
    /// One general GEMM: 2mnk flops.
    fn record(m: usize, n: usize, k: usize) {
        Self::add(1, 2 * (m as u64) * (n as u64) * (k as u64), 0);
    }
    /// One SYRK: the symmetric result costs n²k flops (half a GEMM — the
    /// mirrored half is produced by copying the upper triangle).
    fn record_syrk(n: usize, k: usize) {
        Self::add(1, (n as u64) * (n as u64) * (k as u64), 1);
    }
}

/// Scoped snapshot of the **current thread's** GEMM counters. Deltas are
/// immune to concurrent GEMMs on other threads (recording happens on the
/// calling thread even when the kernel itself runs on the pool), so
/// iteration logs and parallel tests never race on the globals.
pub struct GemmScope {
    calls0: u64,
    flops0: u64,
    syrk0: u64,
}

impl GemmScope {
    pub fn begin() -> GemmScope {
        GemmScope {
            calls0: TL_CALLS.with(|c| c.get()),
            flops0: TL_FLOPS.with(|c| c.get()),
            syrk0: TL_SYRK.with(|c| c.get()),
        }
    }
    /// GEMM + SYRK calls made by this thread since [`GemmScope::begin`].
    pub fn calls(&self) -> u64 {
        TL_CALLS.with(|c| c.get()) - self.calls0
    }
    /// Flops recorded by this thread since [`GemmScope::begin`].
    pub fn flops(&self) -> u64 {
        TL_FLOPS.with(|c| c.get()) - self.flops0
    }
    /// SYRK calls made by this thread since [`GemmScope::begin`] (each is
    /// also included in [`GemmScope::calls`]).
    pub fn syrk_calls(&self) -> u64 {
        TL_SYRK.with(|c| c.get()) - self.syrk0
    }
}

// ───────────────────────── workspace ──────────────────────────

/// A small pool of reusable matrix buffers. `take` hands out (and reshapes)
/// a previously returned buffer or allocates a fresh one; `put` returns a
/// buffer for reuse. Contents of a taken buffer are unspecified — every
/// `*_into` kernel overwrites its full output.
///
/// `take` prefers a free buffer whose backing allocation already fits the
/// requested shape, so a steady state of same-shape take/put cycles performs
/// **zero heap allocations**. [`Workspace::allocations`] counts the takes
/// that could *not* be served that way — the persistent-solver tests assert
/// it stays flat from the second same-shape call onward.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Mat>,
    allocs: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a rows×cols buffer (contents unspecified).
    pub fn take(&mut self, rows: usize, cols: usize) -> Mat {
        let need = rows * cols;
        if let Some(i) = self.free.iter().position(|m| m.capacity() >= need) {
            let mut m = self.free.swap_remove(i);
            m.reset(rows, cols);
            return m;
        }
        // Miss: either grow an undersized free buffer or allocate fresh.
        self.allocs += 1;
        match self.free.pop() {
            Some(mut m) => {
                m.reset(rows, cols);
                m
            }
            None => Mat::zeros(rows, cols),
        }
    }

    /// Return a buffer to the pool for later reuse.
    pub fn put(&mut self, m: Mat) {
        self.free.push(m);
    }

    /// Number of takes that had to allocate (or grow) because no free buffer
    /// was large enough. Flat across calls ⇔ the hot path is allocation-free.
    pub fn allocations(&self) -> usize {
        self.allocs
    }

    /// Number of idle buffers held.
    pub fn len(&self) -> usize {
        self.free.len()
    }
    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

thread_local! {
    /// Per-thread pool for the A/B packing buffers: each pool worker (and
    /// the caller, on the sequential path) reuses its own pair across every
    /// GEMM it runs, so steady-state packing is allocation-free without any
    /// cross-thread sharing.
    static PACK_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

// ───────────────────────── blocking knobs ──────────────────────────

/// Microkernel register tile: MR rows of A × NR columns of B per inner-loop
/// step (MR·NR = 32 independent FMA accumulators).
const MR: usize = 8;
const NR: usize = 4;

/// Cache-block sizes of the packed kernel (see the module docs for the
/// cache-level rationale behind the defaults).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmBlocking {
    /// Rows of A per packed block (L2 resident together with one B panel).
    pub mc: usize,
    /// Shared-dimension extent per packed block. **Changing KC regroups the
    /// reduction** (one register-accumulated partial sum per KC block), so
    /// it may change low-order result bits; fix it once at startup.
    pub kc: usize,
    /// Columns of B per packed block (same bit-level caveat as `kc`).
    pub nc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        GemmBlocking { mc: 128, kc: 256, nc: 512 }
    }
}

impl GemmBlocking {
    /// Parse a `MCxKCxNC` spec, e.g. `128x256x512` (`,` also accepted as the
    /// separator). All three must be ≥ 1.
    pub fn parse(s: &str) -> Result<GemmBlocking> {
        let parts: Vec<&str> = s.split(['x', 'X', ',']).map(str::trim).collect();
        if parts.len() != 3 {
            return Err(Error::Parse(format!(
                "gemm blocking '{s}': expected MCxKCxNC (e.g. 128x256x512)"
            )));
        }
        let mut v = [0usize; 3];
        for (slot, p) in v.iter_mut().zip(&parts) {
            *slot = p
                .parse::<usize>()
                .ok()
                .filter(|&x| x >= 1)
                .ok_or_else(|| Error::Parse(format!("gemm blocking '{s}': bad size '{p}'")))?;
        }
        Ok(GemmBlocking { mc: v[0], kc: v[1], nc: v[2] })
    }

    /// Render back to the `MCxKCxNC` form `parse` accepts.
    pub fn display(&self) -> String {
        format!("{}x{}x{}", self.mc, self.kc, self.nc)
    }

    /// Blocking with the micro-tile minimums enforced (MC ≥ MR, NC ≥ NR).
    fn clamped(self) -> GemmBlocking {
        GemmBlocking { mc: self.mc.max(MR), kc: self.kc.max(1), nc: self.nc.max(NR) }
    }
}

/// Process-global blocking, stored as three atomics so reading it is free of
/// locks on the per-GEMM path. Each kernel invocation snapshots it once.
static GLOBAL_MC: AtomicUsize = AtomicUsize::new(128);
static GLOBAL_KC: AtomicUsize = AtomicUsize::new(256);
static GLOBAL_NC: AtomicUsize = AtomicUsize::new(512);

/// Install process-global cache-block sizes (`--gemm-block` on the CLI,
/// `service.gemm_block` in TOML). A startup-time tuning knob: changing KC/NC
/// regroups reductions and may change low-order bits of later results, so
/// set it before computing anything you intend to compare bitwise.
pub fn set_global_blocking(b: GemmBlocking) {
    let b = b.clamped();
    GLOBAL_MC.store(b.mc, Ordering::Relaxed);
    GLOBAL_KC.store(b.kc, Ordering::Relaxed);
    GLOBAL_NC.store(b.nc, Ordering::Relaxed);
}

/// Current process-global cache-block sizes.
pub fn global_blocking() -> GemmBlocking {
    GemmBlocking {
        mc: GLOBAL_MC.load(Ordering::Relaxed),
        kc: GLOBAL_KC.load(Ordering::Relaxed),
        nc: GLOBAL_NC.load(Ordering::Relaxed),
    }
}

// ───────────────────────── engine ──────────────────────────

/// Minimum C rows per parallel panel — below this the dispatch overhead
/// beats the kernel time, so small products stay sequential.
const MIN_PANEL_ROWS: usize = 16;

/// A strided read-only view of one GEMM operand: element `(i, j)` lives at
/// `data[i·rs + j·cs]`. Lets the packing routines serve `A`, `Aᵀ`, `B`, `Bᵀ`
/// from the original buffers — no transpose is ever materialised.
#[derive(Clone, Copy)]
struct Operand<'a> {
    data: &'a [f64],
    rs: usize,
    cs: usize,
}

impl<'a> Operand<'a> {
    fn normal(m: &'a Mat) -> Operand<'a> {
        Operand { data: m.as_slice(), rs: m.cols(), cs: 1 }
    }
    fn transposed(m: &'a Mat) -> Operand<'a> {
        Operand { data: m.as_slice(), rs: 1, cs: m.cols() }
    }
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.rs + j * self.cs]
    }
}

/// A GEMM execution context: either purely sequential (`pool == None`) or
/// row-panel parallel over a fixed [`ThreadPool`]. Cloning shares the pool.
///
/// Determinism: results are bit-identical for every thread count at a fixed
/// [`GemmBlocking`] (see the module docs); the engine exists so callers can
/// *choose* their parallelism, not so they can get different answers.
#[derive(Clone, Default)]
pub struct GemmEngine {
    pool: Option<Arc<ThreadPool>>,
    /// Engine-local blocking override; `None` reads [`global_blocking`] at
    /// each call.
    blocking: Option<GemmBlocking>,
}

impl GemmEngine {
    /// Sequential engine (no pool, no dispatch overhead).
    pub fn sequential() -> GemmEngine {
        GemmEngine { pool: None, blocking: None }
    }

    /// Engine with its own pool of `threads` workers (1 → sequential).
    pub fn with_threads(threads: usize) -> GemmEngine {
        if threads <= 1 {
            GemmEngine::sequential()
        } else {
            GemmEngine { pool: Some(Arc::new(ThreadPool::new(threads))), blocking: None }
        }
    }

    /// Pin this engine to fixed cache-block sizes instead of the global
    /// knob (benchmark sweeps, tests isolating themselves from the global).
    pub fn with_blocking(mut self, blk: GemmBlocking) -> GemmEngine {
        self.blocking = Some(blk.clamped());
        self
    }

    /// Worker count (1 for the sequential engine).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.size()).unwrap_or(1)
    }

    /// The blocking this engine's kernels run with.
    pub fn blocking(&self) -> GemmBlocking {
        self.blocking.unwrap_or_else(global_blocking)
    }

    /// `C = A·B` into a caller-owned buffer (reshaped in place).
    pub fn matmul_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.rows(), "matmul: {:?} x {:?}", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::normal(b), c.as_mut_slice(), m, n, k, false);
    }

    /// `C = Aᵀ·B` into `c`. The packing stage reads A column-major, so no
    /// transpose is materialised (and no workspace is needed).
    pub fn matmul_at_b_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.rows(), b.rows(), "matmul_at_b: {:?}ᵀ x {:?}", a.shape(), b.shape());
        let (k, m) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::transposed(a), Operand::normal(b), c.as_mut_slice(), m, n, k, false);
    }

    /// `C = A·Bᵀ` into `c` (B read column-major by the packer — no
    /// transpose, no workspace).
    pub fn matmul_a_bt_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.cols(), "matmul_a_bt: {:?} x {:?}ᵀ", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.rows();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::transposed(b), c.as_mut_slice(), m, n, k, false);
    }

    /// Symmetric rank-k `C = AᵀA` into `c`: the packed kernel restricted to
    /// upper-triangle micro-tiles (≈ n²k flops), mirrored afterwards —
    /// exactly symmetric by construction.
    pub fn syrk_at_a_into(&self, c: &mut Mat, a: &Mat) {
        let (k, n) = a.shape();
        GemmCounter::record_syrk(n, k);
        c.reset(n, n);
        c.fill_with(0.0);
        self.dispatch(Operand::transposed(a), Operand::normal(a), c.as_mut_slice(), n, n, k, true);
        mirror_upper(c);
    }

    /// Symmetric rank-k `C = A·Aᵀ` into `c` (same upper-triangle scheme).
    pub fn syrk_a_at_into(&self, c: &mut Mat, a: &Mat) {
        let (m, k) = a.shape();
        GemmCounter::record_syrk(m, k);
        c.reset(m, m);
        c.fill_with(0.0);
        self.dispatch(Operand::normal(a), Operand::transposed(a), c.as_mut_slice(), m, m, k, true);
        mirror_upper(c);
    }

    /// Allocating convenience forms of the `*_into` calls.
    pub fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_into(&mut c, a, b);
        c
    }
    pub fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_at_b_into(&mut c, a, b);
        c
    }
    pub fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_a_bt_into(&mut c, a, b);
        c
    }
    pub fn syrk_at_a(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_at_a_into(&mut c, a);
        c
    }
    pub fn syrk_a_at(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_a_at_into(&mut c, a);
        c
    }

    /// `C += op(A)·op(B)`, dispatched over row panels of C. Each panel runs
    /// the packed kernel over its own rows; for any fixed output element the
    /// accumulation order depends only on the (global) blocking grid, never
    /// on the partition, so the thread count cannot change any output bit.
    /// With `upper_only`, micro-tiles strictly below the diagonal are
    /// skipped (the caller mirrors the upper triangle afterwards).
    fn dispatch(
        &self,
        a: Operand<'_>,
        b: Operand<'_>,
        c: &mut [f64],
        m: usize,
        n: usize,
        k: usize,
        upper_only: bool,
    ) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        // Snapshot the blocking once so every panel of this call agrees.
        let blk = self.blocking().clamped();
        // Floor division: never split below MIN_PANEL_ROWS rows per panel
        // (a sub-minimum panel pays dispatch overhead for no kernel time).
        let blocks = self.threads().min(m / MIN_PANEL_ROWS).max(1);
        match &self.pool {
            Some(pool) if blocks > 1 => {
                let rows_per = m.div_ceil(blocks);
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = c
                    .chunks_mut(rows_per * n)
                    .enumerate()
                    .map(|(bi, cpanel)| {
                        let i0 = bi * rows_per;
                        let rows = cpanel.len() / n;
                        Box::new(move || {
                            gemm_panel(a, b, cpanel, i0, i0 + rows, n, k, blk, upper_only)
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                scoped(pool, jobs);
            }
            _ => gemm_panel(a, b, c, 0, m, n, k, blk, upper_only),
        }
    }
}

// ───────────────────────── global engine ──────────────────────────

/// The process-global engine behind the free functions below. Defaults to
/// sequential; [`set_global_threads`] (driven by `--threads` /
/// `service.gemm_threads`) installs a shared pool.
static GLOBAL_ENGINE: Mutex<Option<GemmEngine>> = Mutex::new(None);

/// Snapshot of the process-global engine. Engines grab this once per run and
/// reuse it, so the mutex is off the per-GEMM path.
pub fn global_engine() -> GemmEngine {
    GLOBAL_ENGINE.lock().unwrap().clone().unwrap_or_default()
}

/// Install a process-global GEMM pool of `threads` workers (1 tears the pool
/// down). Safe to call at any time: results are bit-identical for every
/// thread count, so in-flight callers at the old size stay consistent.
pub fn set_global_threads(threads: usize) {
    let mut g = GLOBAL_ENGINE.lock().unwrap();
    let current = g.as_ref().map(|e| e.threads()).unwrap_or(1);
    if current != threads.max(1) {
        *g = Some(GemmEngine::with_threads(threads));
    }
}

/// Current global GEMM thread count.
pub fn global_threads() -> usize {
    GLOBAL_ENGINE.lock().unwrap().as_ref().map(|e| e.threads()).unwrap_or(1)
}

// ─────────────── free-function API (global engine) ───────────────

/// `C = A · B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul(a, b)
}

/// `C = Aᵀ · B` (A packed column-major — no transpose materialised).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_at_b(a, b)
}

/// `C = A · Bᵀ` (B packed column-major — no transpose materialised).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_a_bt(a, b)
}

/// Symmetric rank-k: `C = Aᵀ A` (exactly symmetric by construction).
pub fn syrk_at_a(a: &Mat) -> Mat {
    global_engine().syrk_at_a(a)
}

/// Symmetric rank-k: `C = A Aᵀ`.
pub fn syrk_a_at(a: &Mat) -> Mat {
    global_engine().syrk_a_at(a)
}

/// `C = A·B` into a reused buffer, on the global engine.
pub fn matmul_into(c: &mut Mat, a: &Mat, b: &Mat) {
    global_engine().matmul_into(c, a, b)
}

/// `C = AᵀA` into a reused buffer, on the global engine.
pub fn syrk_at_a_into(c: &mut Mat, a: &Mat) {
    global_engine().syrk_at_a_into(c, a)
}

// ───────────────────────── packed kernel ──────────────────────────

/// Pack rows `i0..i1`, cols `k0..k1` of `a` into MR-row panels, k-major:
/// panel `p` holds rows `i0+p·MR ..`, stored as `buf[p·kb·MR + t·MR + r]`
/// for k index `t` (0-based within the block) and panel row `r`. Rows past
/// `i1` are zero-padded so the microkernel always runs a full tile.
fn pack_a(buf: &mut [f64], a: Operand<'_>, i0: usize, i1: usize, k0: usize, k1: usize) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut ti = i0;
    while ti < i1 {
        let h = MR.min(i1 - ti);
        for t in 0..kb {
            let dst = &mut buf[off + t * MR..off + t * MR + MR];
            for r in 0..MR {
                dst[r] = if r < h { a.at(ti + r, k0 + t) } else { 0.0 };
            }
        }
        off += kb * MR;
        ti += MR;
    }
}

/// Pack rows `k0..k1`, cols `j0..j1` of `b` into NR-column panels, k-major:
/// panel `p` holds cols `j0+p·NR ..`, stored as `buf[p·kb·NR + t·NR + j]`.
/// Columns past `j1` are zero-padded.
fn pack_b(buf: &mut [f64], b: Operand<'_>, k0: usize, k1: usize, j0: usize, j1: usize) {
    let kb = k1 - k0;
    let mut off = 0;
    let mut js = j0;
    while js < j1 {
        let w = NR.min(j1 - js);
        for t in 0..kb {
            let dst = &mut buf[off + t * NR..off + t * NR + NR];
            for j in 0..NR {
                dst[j] = if j < w { b.at(k0 + t, js + j) } else { 0.0 };
            }
        }
        off += kb * NR;
        js += NR;
    }
}

/// The 8×4 register microkernel: one packed A panel × one packed B panel
/// over `kb` k-steps. All 32 accumulators are independent and the two
/// operand streams are contiguous, so LLVM keeps `acc` in vector registers
/// and turns the inner `j` loop into FMAs (no float-reassociation licence
/// needed — each `acc[r][j]` is its own serial chain).
#[inline(always)]
fn micro_tile(kb: usize, ap: &[f64], bp: &[f64]) -> [f64; MR * NR] {
    let mut acc = [0.0f64; MR * NR];
    let ap = &ap[..kb * MR];
    let bp = &bp[..kb * NR];
    for t in 0..kb {
        let at = &ap[t * MR..t * MR + MR];
        let bt = &bp[t * NR..t * NR + NR];
        for r in 0..MR {
            let ar = at[r];
            for j in 0..NR {
                acc[r * NR + j] += ar * bt[j];
            }
        }
    }
    acc
}

/// Sequential packed kernel over one row panel of C (`rows pi0..pi1`, all n
/// columns; `c` is that panel's row-major storage). `upper_only` skips
/// micro-tiles strictly below the diagonal — used by SYRK; the skipped
/// entries (and any sub-diagonal entries a straddling tile does produce)
/// are overwritten by the caller's mirror pass.
///
/// Determinism invariant (what makes the parallel row split exact): for any
/// fixed element `(i, j)`, the accumulation is "for each (NC, KC) block in
/// grid order: add a register-accumulated k-ordered partial sum". The row
/// partition and the MC/MR grids decide only *which tile* computes an
/// element, never the order of its additions, so callers may split rows
/// anywhere. Zero-padding keeps edge tiles on the same code path.
fn gemm_panel(
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut [f64],
    pi0: usize,
    pi1: usize,
    n: usize,
    k: usize,
    blk: GemmBlocking,
    upper_only: bool,
) {
    if pi0 >= pi1 || n == 0 || k == 0 {
        return;
    }
    let GemmBlocking { mc, kc, nc } = blk;
    PACK_WS.with(|ws| {
        let mut ws = ws.borrow_mut();
        let mut apack = ws.take(1, mc.div_ceil(MR) * MR * kc);
        let mut bpack = ws.take(1, nc.div_ceil(NR) * NR * kc);
        for jc in (0..n).step_by(nc) {
            let j1 = (jc + nc).min(n);
            // SYRK: a row panel entirely below this column block has no
            // upper-triangle work at all — skip before packing any B panel.
            if upper_only && pi0 >= j1 {
                continue;
            }
            for k0 in (0..k).step_by(kc) {
                let k1 = (k0 + kc).min(k);
                let kb = k1 - k0;
                pack_b(bpack.as_mut_slice(), b, k0, k1, jc, j1);
                for ic in (pi0..pi1).step_by(mc) {
                    let i1 = (ic + mc).min(pi1);
                    // SYRK: a whole A block strictly below this column block
                    // contributes no upper-triangle element — skip it before
                    // paying for the pack.
                    if upper_only && ic >= j1 {
                        continue;
                    }
                    pack_a(apack.as_mut_slice(), a, ic, i1, k0, k1);
                    let mut si = 0;
                    let mut js = jc;
                    while js < j1 {
                        let w = NR.min(j1 - js);
                        let bstrip = &bpack.as_slice()[si * kb * NR..(si + 1) * kb * NR];
                        let mut tile = 0;
                        let mut ti = ic;
                        while ti < i1 {
                            let h = MR.min(i1 - ti);
                            // Upper-triangle filter at micro-tile grain: a
                            // tile whose first row is past the strip's last
                            // column holds no (i ≤ j) element. The test uses
                            // global indices, so every upper element is
                            // computed under any row partition.
                            if !upper_only || ti < js + NR {
                                let astrip =
                                    &apack.as_slice()[tile * kb * MR..(tile + 1) * kb * MR];
                                let acc = micro_tile(kb, astrip, bstrip);
                                for r in 0..h {
                                    let base = (ti - pi0 + r) * n + js;
                                    let row = &mut c[base..base + w];
                                    for j in 0..w {
                                        row[j] += acc[r * NR + j];
                                    }
                                }
                            }
                            tile += 1;
                            ti += MR;
                        }
                        si += 1;
                        js += NR;
                    }
                }
            }
        }
        ws.put(apack);
        ws.put(bpack);
    });
}

/// Copy the upper triangle into the lower one (exact symmetry).
fn mirror_upper(c: &mut Mat) {
    let n = c.rows();
    for i in 1..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

// ───────────────── reference / ablation kernels ──────────────────

/// The seed's broadcast-FMA kernel: `C[m x n] += A[m x k] · B[k x n]`, both
/// row-major. Kept as the §Perf ablation baseline (`perf_gemm` reports the
/// packed kernel's speedup over it) and as a second independent
/// implementation for conformance cross-checks.
///
/// Loop order (jc, kc, i, t, j): the innermost `crow[j] += a_it * brow[j]`
/// has no cross-iteration dependence, so rustc vectorises it into FMAs. The
/// (KC2 × NC) B panel stays hot in L2 across the whole i sweep; a 4-row
/// micro-tile quarters the B bandwidth. Unlike the packed kernel it never
/// copies its operands — which is exactly what costs it at large n: A and C
/// rows are touched with stride n, so TLB/cache-line utilisation degrades
/// where the packed kernel keeps streaming contiguous panels.
pub fn gemm_broadcast(a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    const NC: usize = 512; // B-panel columns (NC·KC2·8B = 512 KiB ≤ L2)
    const KC2: usize = 256; // B-panel rows
    for j0 in (0..n).step_by(NC) {
        let j1 = (j0 + NC).min(n);
        for k0 in (0..k).step_by(KC2) {
            let k1 = (k0 + KC2).min(k);
            let mut i = 0;
            while i + 4 <= m {
                let (rows01, rows23) = (&mut c[i * n..(i + 4) * n]).split_at_mut(2 * n);
                let (row0, row1) = rows01.split_at_mut(n);
                let (row2, row3) = rows23.split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let c2 = &mut row2[j0..j1];
                let c3 = &mut row3[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for t in k0..k1 {
                    let (av0, av1, av2, av3) = (a0[t], a1[t], a2[t], a3[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((((c0v, c1v), c2v), c3v), bv) in c0
                        .iter_mut()
                        .zip(c1.iter_mut())
                        .zip(c2.iter_mut())
                        .zip(c3.iter_mut())
                        .zip(brow)
                    {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                        *c2v += av2 * bv;
                        *c3v += av3 * bv;
                    }
                }
                i += 4;
            }
            while i + 2 <= m {
                let (row0, row1) = (&mut c[i * n..(i + 2) * n]).split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                for t in k0..k1 {
                    let (av0, av1) = (a0[t], a1[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((c0v, c1v), bv) in c0.iter_mut().zip(c1.iter_mut()).zip(brow) {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                    }
                }
                i += 2;
            }
            if i < m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for t in k0..k1 {
                    let av = a[i * k + t];
                    let brow = &b[t * n + j0..t * n + j1];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// Reference (naive) matmul for tests.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for t in 0..k {
            let av = a[(i, t)];
            for j in 0..n {
                c[(i, j)] += av * b[(t, j)];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: &Mat, b: &Mat, tol: f64) -> bool {
        a.shape() == b.shape() && a.sub(b).max_abs() < tol
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 13, 9), (64, 64, 64), (65, 130, 33)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 20, 20, 1.0);
        assert!(close(&matmul(&a, &Mat::eye(20)), &a, 1e-12));
        assert!(close(&matmul(&Mat::eye(20), &a), &a, 1e-12));
    }

    #[test]
    fn at_b_and_a_bt_match() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 12, 7, 1.0);
        let b = Mat::gaussian(&mut rng, 12, 9, 1.0);
        let want = matmul_naive(&a.transpose(), &b);
        assert!(close(&matmul_at_b(&a, &b), &want, 1e-10));

        let c = Mat::gaussian(&mut rng, 9, 7, 1.0);
        let want2 = matmul_naive(&a, &c.transpose());
        assert!(close(&matmul_a_bt(&a, &c), &want2, 1e-10));
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::gaussian(&mut rng, 15, 8, 1.0);
        let want = matmul_naive(&a.transpose(), &a);
        let got = syrk_at_a(&a);
        assert!(close(&got, &want, 1e-10));
        assert_eq!(got.symmetry_defect(), 0.0);

        let want2 = matmul_naive(&a, &a.transpose());
        let got2 = syrk_a_at(&a);
        assert!(close(&got2, &want2, 1e-10));
        assert_eq!(got2.symmetry_defect(), 0.0);
    }

    #[test]
    fn gemm_counter_increments() {
        let before = GemmCounter::calls();
        let mut rng = Rng::seed_from(5);
        let a = Mat::gaussian(&mut rng, 4, 4, 1.0);
        let _ = matmul(&a, &a);
        assert!(GemmCounter::calls() > before);
        assert!(GemmCounter::flops() > 0);
    }

    #[test]
    fn into_calls_record_once_and_syrk_counts_half() {
        let mut rng = Rng::seed_from(6);
        let a = Mat::gaussian(&mut rng, 6, 4, 1.0);
        let b = Mat::gaussian(&mut rng, 4, 3, 1.0);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);

        let scope = GemmScope::begin();
        eng.matmul_into(&mut c, &a, &b);
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 0);
        assert_eq!(scope.flops(), 2 * 6 * 3 * 4);

        let scope = GemmScope::begin();
        eng.syrk_at_a_into(&mut c, &a); // AᵀA: n=4, k=6 → n²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 1);
        assert_eq!(scope.flops(), 4 * 4 * 6);

        let scope = GemmScope::begin();
        eng.syrk_a_at_into(&mut c, &a); // AAᵀ: m=6, k=4 → m²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.syrk_calls(), 1);
        assert_eq!(scope.flops(), 6 * 6 * 4);
    }

    #[test]
    fn into_reuses_buffers_across_shapes() {
        let mut rng = Rng::seed_from(7);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);
        for &(m, k, n) in &[(5, 7, 3), (2, 2, 2), (9, 4, 11)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            eng.matmul_into(&mut c, &a, &b);
            assert!(close(&c, &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_engine_bit_identical_to_sequential() {
        let mut rng = Rng::seed_from(8);
        let seq = GemmEngine::sequential();
        let par = GemmEngine::with_threads(4);
        // Sizes straddling the MIN_PANEL_ROWS threshold and ragged splits.
        for &(m, k, n) in &[(1, 3, 2), (16, 16, 16), (33, 17, 29), (70, 40, 55)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let c_seq = seq.matmul(&a, &b);
            let c_par = par.matmul(&a, &b);
            assert_eq!(c_seq, c_par, "matmul {m}x{k}x{n} not bit-identical");
            let s_seq = seq.syrk_at_a(&a);
            let s_par = par.syrk_at_a(&a);
            assert_eq!(s_seq, s_par, "syrk {m}x{k} not bit-identical");
        }
    }

    #[test]
    fn custom_blocking_stays_correct() {
        // Tiny blocks force every edge path (ragged tiles, many KC/NC
        // blocks) without touching the process-global knob.
        let mut rng = Rng::seed_from(9);
        let blk = GemmBlocking { mc: 8, kc: 5, nc: 7 };
        let eng = GemmEngine::sequential().with_blocking(blk);
        assert_eq!(eng.blocking(), blk.clamped());
        for &(m, k, n) in &[(1, 1, 1), (13, 11, 9), (40, 23, 31)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(
                close(&eng.matmul(&a, &b), &matmul_naive(&a, &b), 1e-10),
                "blocked {m}x{k}x{n}"
            );
            let sa = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(
                &eng.syrk_at_a(&sa),
                &matmul_naive(&sa.transpose(), &sa),
                1e-10
            ));
        }
        // And a parallel engine at the same blocking stays bit-identical.
        let par = GemmEngine::with_threads(3).with_blocking(blk);
        let a = Mat::gaussian(&mut rng, 70, 19, 1.0);
        let b = Mat::gaussian(&mut rng, 19, 26, 1.0);
        assert_eq!(eng.matmul(&a, &b), par.matmul(&a, &b));
    }

    #[test]
    fn blocking_parse_roundtrip() {
        let b = GemmBlocking::parse("64x128x256").unwrap();
        assert_eq!(b, GemmBlocking { mc: 64, kc: 128, nc: 256 });
        assert_eq!(GemmBlocking::parse(&b.display()).unwrap(), b);
        assert_eq!(
            GemmBlocking::parse("64,128,256").unwrap(),
            GemmBlocking { mc: 64, kc: 128, nc: 256 }
        );
        assert!(GemmBlocking::parse("64x128").is_err());
        assert!(GemmBlocking::parse("64x0x256").is_err());
        assert!(GemmBlocking::parse("axbxc").is_err());
    }

    #[test]
    fn global_blocking_roundtrip() {
        // Only ever set the default value here: the global knob is
        // bit-level observable, and unit tests run concurrently.
        set_global_blocking(GemmBlocking::default());
        assert_eq!(global_blocking(), GemmBlocking::default());
    }

    #[test]
    fn broadcast_kernel_matches_packed() {
        let mut rng = Rng::seed_from(10);
        for &(m, k, n) in &[(5, 9, 3), (33, 20, 41)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let mut c = Mat::zeros(m, n);
            gemm_broadcast(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
            assert!(close(&c, &matmul(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn workspace_recycles() {
        let mut ws = Workspace::new();
        let m1 = ws.take(4, 4);
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1);
        ws.put(m1);
        assert_eq!(ws.len(), 1);
        let m2 = ws.take(2, 6); // reshaped reuse: 12 elems fit in capacity 16
        assert_eq!(m2.shape(), (2, 6));
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1, "fitting reuse must not count as alloc");
    }

    #[test]
    fn workspace_prefers_fitting_buffer() {
        let mut ws = Workspace::new();
        let small = ws.take(2, 2);
        let big = ws.take(8, 8);
        ws.put(small);
        ws.put(big);
        assert_eq!(ws.allocations(), 2);
        // A 6x6 request skips the 2x2 buffer and reuses the 8x8 one.
        let m = ws.take(6, 6);
        assert_eq!(m.shape(), (6, 6));
        assert_eq!(ws.allocations(), 2);
        assert_eq!(ws.len(), 1);
        // Nothing fits 10x10: counts as an allocation (grown in place).
        let g = ws.take(10, 10);
        assert_eq!(g.shape(), (10, 10));
        assert_eq!(ws.allocations(), 3);
    }

    #[test]
    fn global_threads_roundtrip() {
        // Default is sequential; setting 1 keeps it sequential. (Setting >1
        // here would leak a pool into unrelated unit tests' timing, so the
        // parallel paths are covered by the local-engine tests above.)
        set_global_threads(1);
        assert_eq!(global_threads(), 1);
        assert_eq!(global_engine().threads(), 1);
    }
}
