//! Parallel, workspace-reusing GEMM and symmetric rank-k engine.
//!
//! This is the O(n³) hot path of every Newton–Schulz-like iteration. The
//! layer has three pieces:
//!
//! 1. **The kernel** — a sequential **broadcast-FMA** design (post-§Perf,
//!    see EXPERIMENTS.md): loop order (jc, kc, i, t, j) whose innermost loop
//!    is a dependence-free `c[j] += a·b[j]` stream, auto-vectorised to
//!    AVX-512 FMAs; a 4-row micro-tile so each B panel row read from L2
//!    feeds four C rows; SYRK via rank-1 updates on the upper triangle,
//!    mirrored at the end.
//! 2. **The engine** — [`GemmEngine`] partitions the rows of C into
//!    contiguous panels and runs the kernel on each panel over the crate's
//!    [`crate::threads::ThreadPool`] (via [`crate::threads::scoped`]). Each
//!    output row's floating-point operation sequence is identical in every
//!    partition (the micro-tile variants interleave rows but never reorder a
//!    single row's accumulation), so results are **bit-identical for every
//!    pool size** — pool-of-8 output equals sequential output exactly. With
//!    `threads() == 1` (the default global engine) no pool is touched and
//!    the call degrades to the plain sequential kernel.
//! 3. **The workspace API** — `*_into` variants write into caller-owned
//!    output buffers (reshaped in place, allocation reused), and
//!    [`Workspace`] is a small buffer pool for the transposes/temporaries a
//!    call needs. The iteration engines hold ping-pong buffers for their
//!    whole run, so after iteration 0 the hot loop performs **zero heap
//!    allocation**.
//!
//! The previous packed dot-product kernel is kept as [`gemm_packed`]: it is
//! the §Perf ablation subject and the independent reference implementation
//! the conformance property tests cross-check against.
//!
//! GEMM-call counting: the PRISM paper reports costs in units of GEMMs; the
//! engines count their invocations through [`GemmCounter`]. Counts are kept
//! both process-globally and per-thread; [`GemmScope`] reads the per-thread
//! counters so concurrent runs (service workers, parallel tests) never see
//! each other's calls. SYRK records its true n²k flop count, not the 2mnk
//! of a general GEMM.

use super::Mat;
use crate::threads::{scoped, ThreadPool};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Process-wide GEMM counters (cheap relaxed atomics) plus thread-local
/// shadows for race-free per-run accounting.
static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_FLOPS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL_CALLS: Cell<u64> = Cell::new(0);
    static TL_FLOPS: Cell<u64> = Cell::new(0);
}

pub struct GemmCounter;

impl GemmCounter {
    /// Process-wide call count (all threads).
    pub fn calls() -> u64 {
        GEMM_CALLS.load(Ordering::Relaxed)
    }
    /// Process-wide flop count (all threads).
    pub fn flops() -> u64 {
        GEMM_FLOPS.load(Ordering::Relaxed)
    }
    fn add(calls: u64, flops: u64) {
        GEMM_CALLS.fetch_add(calls, Ordering::Relaxed);
        GEMM_FLOPS.fetch_add(flops, Ordering::Relaxed);
        TL_CALLS.with(|c| c.set(c.get() + calls));
        TL_FLOPS.with(|c| c.set(c.get() + flops));
    }
    /// One general GEMM: 2mnk flops.
    fn record(m: usize, n: usize, k: usize) {
        Self::add(1, 2 * (m as u64) * (n as u64) * (k as u64));
    }
    /// One SYRK: the symmetric result costs n²k flops (half a GEMM).
    fn record_syrk(n: usize, k: usize) {
        Self::add(1, (n as u64) * (n as u64) * (k as u64));
    }
}

/// Scoped snapshot of the **current thread's** GEMM counters. Deltas are
/// immune to concurrent GEMMs on other threads (recording happens on the
/// calling thread even when the kernel itself runs on the pool), so
/// iteration logs and parallel tests never race on the globals.
pub struct GemmScope {
    calls0: u64,
    flops0: u64,
}

impl GemmScope {
    pub fn begin() -> GemmScope {
        GemmScope { calls0: TL_CALLS.with(|c| c.get()), flops0: TL_FLOPS.with(|c| c.get()) }
    }
    /// GEMM calls made by this thread since [`GemmScope::begin`].
    pub fn calls(&self) -> u64 {
        TL_CALLS.with(|c| c.get()) - self.calls0
    }
    /// Flops recorded by this thread since [`GemmScope::begin`].
    pub fn flops(&self) -> u64 {
        TL_FLOPS.with(|c| c.get()) - self.flops0
    }
}

// ───────────────────────── workspace ──────────────────────────

/// A small pool of reusable matrix buffers. `take` hands out (and reshapes)
/// a previously returned buffer or allocates a fresh one; `put` returns a
/// buffer for reuse. Contents of a taken buffer are unspecified — every
/// `*_into` kernel overwrites its full output.
///
/// `take` prefers a free buffer whose backing allocation already fits the
/// requested shape, so a steady state of same-shape take/put cycles performs
/// **zero heap allocations**. [`Workspace::allocations`] counts the takes
/// that could *not* be served that way — the persistent-solver tests assert
/// it stays flat from the second same-shape call onward.
#[derive(Default)]
pub struct Workspace {
    free: Vec<Mat>,
    allocs: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Take a rows×cols buffer (contents unspecified).
    pub fn take(&mut self, rows: usize, cols: usize) -> Mat {
        let need = rows * cols;
        if let Some(i) = self.free.iter().position(|m| m.capacity() >= need) {
            let mut m = self.free.swap_remove(i);
            m.reset(rows, cols);
            return m;
        }
        // Miss: either grow an undersized free buffer or allocate fresh.
        self.allocs += 1;
        match self.free.pop() {
            Some(mut m) => {
                m.reset(rows, cols);
                m
            }
            None => Mat::zeros(rows, cols),
        }
    }

    /// Return a buffer to the pool for later reuse.
    pub fn put(&mut self, m: Mat) {
        self.free.push(m);
    }

    /// Number of takes that had to allocate (or grow) because no free buffer
    /// was large enough. Flat across calls ⇔ the hot path is allocation-free.
    pub fn allocations(&self) -> usize {
        self.allocs
    }

    /// Number of idle buffers held.
    pub fn len(&self) -> usize {
        self.free.len()
    }
    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

// ───────────────────────── engine ──────────────────────────

/// Minimum C rows per parallel panel — below this the dispatch overhead
/// beats the kernel time, so small products stay sequential.
const MIN_PANEL_ROWS: usize = 16;

/// A GEMM execution context: either purely sequential (`pool == None`) or
/// row-panel parallel over a fixed [`ThreadPool`]. Cloning shares the pool.
///
/// Determinism: results are bit-identical for every thread count (see the
/// module docs); the engine exists so callers can *choose* their
/// parallelism, not so they can get different answers.
#[derive(Clone, Default)]
pub struct GemmEngine {
    pool: Option<Arc<ThreadPool>>,
}

impl GemmEngine {
    /// Sequential engine (no pool, no dispatch overhead).
    pub fn sequential() -> GemmEngine {
        GemmEngine { pool: None }
    }

    /// Engine with its own pool of `threads` workers (1 → sequential).
    pub fn with_threads(threads: usize) -> GemmEngine {
        if threads <= 1 {
            GemmEngine::sequential()
        } else {
            GemmEngine { pool: Some(Arc::new(ThreadPool::new(threads))) }
        }
    }

    /// Worker count (1 for the sequential engine).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.size()).unwrap_or(1)
    }

    /// `C = A·B` into a caller-owned buffer (reshaped in place).
    pub fn matmul_into(&self, c: &mut Mat, a: &Mat, b: &Mat) {
        assert_eq!(a.cols(), b.rows(), "matmul: {:?} x {:?}", a.shape(), b.shape());
        let (m, k) = a.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.gemm(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
    }

    /// `C = Aᵀ·B` into `c` (one O(mk) transpose through `ws`).
    pub fn matmul_at_b_into(&self, c: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
        assert_eq!(a.rows(), b.rows(), "matmul_at_b: {:?}ᵀ x {:?}", a.shape(), b.shape());
        let mut at = ws.take(a.cols(), a.rows());
        a.transpose_into(&mut at);
        let (m, k) = at.shape();
        let n = b.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.gemm(at.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
        ws.put(at);
    }

    /// `C = A·Bᵀ` into `c` (one O(nk) transpose through `ws`).
    pub fn matmul_a_bt_into(&self, c: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
        assert_eq!(a.cols(), b.cols(), "matmul_a_bt: {:?} x {:?}ᵀ", a.shape(), b.shape());
        let mut bt = ws.take(b.cols(), b.rows());
        b.transpose_into(&mut bt);
        let (m, k) = a.shape();
        let n = bt.cols();
        GemmCounter::record(m, n, k);
        c.reset(m, n);
        c.fill_with(0.0);
        self.gemm(a.as_slice(), bt.as_slice(), c.as_mut_slice(), m, n, k);
        ws.put(bt);
    }

    /// Symmetric rank-k `C = AᵀA` into `c` (exactly symmetric by
    /// construction; records n²k flops).
    pub fn syrk_at_a_into(&self, c: &mut Mat, a: &Mat) {
        let (k, n) = a.shape();
        GemmCounter::record_syrk(n, k);
        c.reset(n, n);
        c.fill_with(0.0);
        self.syrk_upper(a, c.as_mut_slice(), n);
        mirror_upper(c);
    }

    /// Symmetric rank-k `C = A·Aᵀ` into `c` (via the rank-1 kernel on Aᵀ's
    /// rows; one O(mk) transpose through `ws` keeps the hot loop contiguous).
    pub fn syrk_a_at_into(&self, c: &mut Mat, a: &Mat, ws: &mut Workspace) {
        let (m, k) = a.shape();
        GemmCounter::record_syrk(m, k);
        let mut at = ws.take(k, m);
        a.transpose_into(&mut at);
        c.reset(m, m);
        c.fill_with(0.0);
        self.syrk_upper(&at, c.as_mut_slice(), m);
        mirror_upper(c);
        ws.put(at);
    }

    /// Allocating convenience forms of the `*_into` calls.
    pub fn matmul(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_into(&mut c, a, b);
        c
    }
    pub fn matmul_at_b(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_at_b_into(&mut c, a, b, &mut Workspace::new());
        c
    }
    pub fn matmul_a_bt(&self, a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.matmul_a_bt_into(&mut c, a, b, &mut Workspace::new());
        c
    }
    pub fn syrk_at_a(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_at_a_into(&mut c, a);
        c
    }
    pub fn syrk_a_at(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(0, 0);
        self.syrk_a_at_into(&mut c, a, &mut Workspace::new());
        c
    }

    /// `C += A·B`, dispatched over row panels of C. Each panel is a plain
    /// sequential kernel run over its own rows of A and C, so the partition
    /// (and hence the thread count) cannot change any output bit.
    fn gemm(&self, a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
        if m == 0 || n == 0 || k == 0 {
            return;
        }
        // Floor division: never split below MIN_PANEL_ROWS rows per panel
        // (a sub-minimum panel pays dispatch overhead for no kernel time).
        let blocks = self.threads().min(m / MIN_PANEL_ROWS).max(1);
        match &self.pool {
            Some(pool) if blocks > 1 => {
                let rows_per = (m + blocks - 1) / blocks;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = c
                    .chunks_mut(rows_per * n)
                    .enumerate()
                    .map(|(bi, cpanel)| {
                        let i0 = bi * rows_per;
                        let rows = cpanel.len() / n;
                        let apanel = &a[i0 * k..(i0 + rows) * k];
                        Box::new(move || gemm_broadcast(apanel, b, cpanel, rows, n, k))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                scoped(pool, jobs);
            }
            _ => gemm_broadcast(a, b, c, m, n, k),
        }
    }

    /// Upper-triangle SYRK (`c[i, i..] += Σ_t a[t,i]·a[t, i..]`), dispatched
    /// over row panels of C with the same determinism argument as `gemm`.
    fn syrk_upper(&self, a: &Mat, c: &mut [f64], n: usize) {
        if n == 0 {
            return;
        }
        let blocks = self.threads().min(n / MIN_PANEL_ROWS).max(1);
        match &self.pool {
            Some(pool) if blocks > 1 => {
                let rows_per = (n + blocks - 1) / blocks;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = c
                    .chunks_mut(rows_per * n)
                    .enumerate()
                    .map(|(bi, cpanel)| {
                        let i0 = bi * rows_per;
                        let rows = cpanel.len() / n;
                        Box::new(move || syrk_rank1_rows(a, cpanel, i0, i0 + rows, n))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                scoped(pool, jobs);
            }
            _ => syrk_rank1_rows(a, c, 0, n, n),
        }
    }
}

// ───────────────────────── global engine ──────────────────────────

/// The process-global engine behind the free functions below. Defaults to
/// sequential; [`set_global_threads`] (driven by `--threads` /
/// `service.gemm_threads`) installs a shared pool.
static GLOBAL_ENGINE: Mutex<Option<GemmEngine>> = Mutex::new(None);

/// Snapshot of the process-global engine. Engines grab this once per run and
/// reuse it, so the mutex is off the per-GEMM path.
pub fn global_engine() -> GemmEngine {
    GLOBAL_ENGINE.lock().unwrap().clone().unwrap_or_default()
}

/// Install a process-global GEMM pool of `threads` workers (1 tears the pool
/// down). Safe to call at any time: results are bit-identical for every
/// thread count, so in-flight callers at the old size stay consistent.
pub fn set_global_threads(threads: usize) {
    let mut g = GLOBAL_ENGINE.lock().unwrap();
    let current = g.as_ref().map(|e| e.threads()).unwrap_or(1);
    if current != threads.max(1) {
        *g = Some(GemmEngine::with_threads(threads));
    }
}

/// Current global GEMM thread count.
pub fn global_threads() -> usize {
    GLOBAL_ENGINE.lock().unwrap().as_ref().map(|e| e.threads()).unwrap_or(1)
}

// ─────────────── free-function API (global engine) ───────────────

/// `C = A · B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul(a, b)
}

/// `C = Aᵀ · B` (one O(mk) transpose, then the broadcast kernel).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_at_b(a, b)
}

/// `C = A · Bᵀ` (one O(nk) transpose, then the broadcast kernel).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    global_engine().matmul_a_bt(a, b)
}

/// Symmetric rank-k: `C = Aᵀ A` (exactly symmetric by construction).
pub fn syrk_at_a(a: &Mat) -> Mat {
    global_engine().syrk_at_a(a)
}

/// Symmetric rank-k: `C = A Aᵀ`.
pub fn syrk_a_at(a: &Mat) -> Mat {
    global_engine().syrk_a_at(a)
}

/// `C = A·B` into a reused buffer, on the global engine.
pub fn matmul_into(c: &mut Mat, a: &Mat, b: &Mat) {
    global_engine().matmul_into(c, a, b)
}

/// `C = AᵀA` into a reused buffer, on the global engine.
pub fn syrk_at_a_into(c: &mut Mat, a: &Mat) {
    global_engine().syrk_at_a_into(c, a)
}

// ───────────────────────── kernels ──────────────────────────

/// Copy the upper triangle into the lower one (exact symmetry).
fn mirror_upper(c: &mut Mat) {
    let n = c.rows();
    for i in 1..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

/// Rank-1 SYRK rows: for C rows `i0..i1` (passed as the slice `c_rows`),
/// accumulate `C[i, i..] += a[t, i] · a[t, i..]` over every row t of `a`.
/// The inner stream is contiguous and dependence-free, so it vectorises
/// like the GEMM kernel (§Perf change 3).
fn syrk_rank1_rows(a: &Mat, c_rows: &mut [f64], i0: usize, i1: usize, n: usize) {
    let k = a.rows();
    for t in 0..k {
        let row = a.row(t);
        for i in i0..i1 {
            let av = row[i];
            let off = (i - i0) * n;
            let ci = &mut c_rows[off + i..off + n];
            for (cv, rv) in ci.iter_mut().zip(&row[i..]) {
                *cv += av * rv;
            }
        }
    }
}

/// Broadcast-FMA kernel: `C[m x n] += A[m x k] · B[k x n]`, both row-major.
///
/// Loop order (jc, kc, i, t, j): the innermost `crow[j] += a_it * brow[j]`
/// has no cross-iteration dependence, so rustc vectorises it into AVX-512
/// FMAs (a dot-product reduction kernel cannot be auto-vectorised without
/// float-reassociation licence). The (KC2 × NC) B panel stays hot in L2
/// across the whole i sweep, and each C row segment stays in L1 across the
/// t loop. §Perf change 2: 1.6–2.4x over the packed dot-product kernel.
///
/// Per-row determinism invariant (what makes the parallel dispatch exact):
/// for any fixed output row, the 4-/2-/1-row micro-tile variants all execute
/// the same `(j0, k0, t, j)` accumulation sequence — tiles interleave rows
/// but never reorder within one. Callers may therefore split `m` anywhere.
fn gemm_broadcast(a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    const NC: usize = 512; // B-panel columns (NC·KC2·8B = 512 KiB ≤ L2)
    const KC2: usize = 256; // B-panel rows
    for j0 in (0..n).step_by(NC) {
        let j1 = (j0 + NC).min(n);
        for k0 in (0..k).step_by(KC2) {
            let k1 = (k0 + KC2).min(k);
            // 4-row micro-tile: each B row loaded from L2 feeds four C rows'
            // FMA streams (§Perf changes 4/5 — B bandwidth quartered).
            let mut i = 0;
            while i + 4 <= m {
                let (rows01, rows23) = (&mut c[i * n..(i + 4) * n]).split_at_mut(2 * n);
                let (row0, row1) = rows01.split_at_mut(n);
                let (row2, row3) = rows23.split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let c2 = &mut row2[j0..j1];
                let c3 = &mut row3[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for t in k0..k1 {
                    let (av0, av1, av2, av3) = (a0[t], a1[t], a2[t], a3[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((((c0v, c1v), c2v), c3v), bv) in c0
                        .iter_mut()
                        .zip(c1.iter_mut())
                        .zip(c2.iter_mut())
                        .zip(c3.iter_mut())
                        .zip(brow)
                    {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                        *c2v += av2 * bv;
                        *c3v += av3 * bv;
                    }
                }
                i += 4;
            }
            while i + 2 <= m {
                let (row0, row1) = (&mut c[i * n..(i + 2) * n]).split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                for t in k0..k1 {
                    let (av0, av1) = (a0[t], a1[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((c0v, c1v), bv) in c0.iter_mut().zip(c1.iter_mut()).zip(brow) {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                    }
                }
                i += 2;
            }
            if i < m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for t in k0..k1 {
                    let av = a[i * k + t];
                    let brow = &b[t * n + j0..t * n + j1];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

const MC: usize = 64; // rows of A per block (packed reference kernel)
const KC: usize = 256; // shared dim per block (packed reference kernel)

/// Former core kernel (packed dot-product): kept for the §Perf ablation and
/// as the independent reference implementation the conformance property
/// tests cross-check against. `bt` is B **pre-transposed** (n × k row-major).
pub fn gemm_packed(a: &[f64], bt: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a[i * k + k0..i * k + k1];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                // 2-column unroll: amortises the A-row reload.
                while j + 2 <= n {
                    let b0 = &bt[j * k + k0..j * k + k1];
                    let b1 = &bt[(j + 1) * k + k0..(j + 1) * k + k1];
                    let (mut s0a, mut s0b) = (0.0, 0.0);
                    let (mut s1a, mut s1b) = (0.0, 0.0);
                    let len = arow.len();
                    let mut t = 0;
                    while t + 2 <= len {
                        s0a += arow[t] * b0[t];
                        s0b += arow[t + 1] * b0[t + 1];
                        s1a += arow[t] * b1[t];
                        s1b += arow[t + 1] * b1[t + 1];
                        t += 2;
                    }
                    while t < len {
                        s0a += arow[t] * b0[t];
                        s1a += arow[t] * b1[t];
                        t += 1;
                    }
                    crow[j] += s0a + s0b;
                    crow[j + 1] += s1a + s1b;
                    j += 2;
                }
                while j < n {
                    let brow = &bt[j * k + k0..j * k + k1];
                    let mut acc = 0.0;
                    for t in 0..arow.len() {
                        acc += arow[t] * brow[t];
                    }
                    crow[j] += acc;
                    j += 1;
                }
            }
        }
    }
}

/// Reference (naive) matmul for tests.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for t in 0..k {
            let av = a[(i, t)];
            for j in 0..n {
                c[(i, j)] += av * b[(t, j)];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: &Mat, b: &Mat, tol: f64) -> bool {
        a.shape() == b.shape() && a.sub(b).max_abs() < tol
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 13, 9), (64, 64, 64), (65, 130, 33)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 20, 20, 1.0);
        assert!(close(&matmul(&a, &Mat::eye(20)), &a, 1e-12));
        assert!(close(&matmul(&Mat::eye(20), &a), &a, 1e-12));
    }

    #[test]
    fn at_b_and_a_bt_match() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 12, 7, 1.0);
        let b = Mat::gaussian(&mut rng, 12, 9, 1.0);
        let want = matmul_naive(&a.transpose(), &b);
        assert!(close(&matmul_at_b(&a, &b), &want, 1e-10));

        let c = Mat::gaussian(&mut rng, 9, 7, 1.0);
        let want2 = matmul_naive(&a, &c.transpose());
        assert!(close(&matmul_a_bt(&a, &c), &want2, 1e-10));
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::gaussian(&mut rng, 15, 8, 1.0);
        let want = matmul_naive(&a.transpose(), &a);
        let got = syrk_at_a(&a);
        assert!(close(&got, &want, 1e-10));
        assert_eq!(got.symmetry_defect(), 0.0);

        let want2 = matmul_naive(&a, &a.transpose());
        let got2 = syrk_a_at(&a);
        assert!(close(&got2, &want2, 1e-10));
        assert_eq!(got2.symmetry_defect(), 0.0);
    }

    #[test]
    fn gemm_counter_increments() {
        let before = GemmCounter::calls();
        let mut rng = Rng::seed_from(5);
        let a = Mat::gaussian(&mut rng, 4, 4, 1.0);
        let _ = matmul(&a, &a);
        assert!(GemmCounter::calls() > before);
        assert!(GemmCounter::flops() > 0);
    }

    #[test]
    fn into_calls_record_once_and_syrk_counts_half() {
        let mut rng = Rng::seed_from(6);
        let a = Mat::gaussian(&mut rng, 6, 4, 1.0);
        let b = Mat::gaussian(&mut rng, 4, 3, 1.0);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);

        let scope = GemmScope::begin();
        eng.matmul_into(&mut c, &a, &b);
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.flops(), 2 * 6 * 3 * 4);

        let scope = GemmScope::begin();
        eng.syrk_at_a_into(&mut c, &a); // AᵀA: n=4, k=6 → n²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.flops(), 4 * 4 * 6);

        let scope = GemmScope::begin();
        let mut ws = Workspace::new();
        eng.syrk_a_at_into(&mut c, &a, &mut ws); // AAᵀ: m=6, k=4 → m²k flops
        assert_eq!(scope.calls(), 1);
        assert_eq!(scope.flops(), 6 * 6 * 4);
    }

    #[test]
    fn into_reuses_buffers_across_shapes() {
        let mut rng = Rng::seed_from(7);
        let eng = GemmEngine::sequential();
        let mut c = Mat::zeros(0, 0);
        for &(m, k, n) in &[(5, 7, 3), (2, 2, 2), (9, 4, 11)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            eng.matmul_into(&mut c, &a, &b);
            assert!(close(&c, &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_engine_bit_identical_to_sequential() {
        let mut rng = Rng::seed_from(8);
        let seq = GemmEngine::sequential();
        let par = GemmEngine::with_threads(4);
        // Sizes straddling the MIN_PANEL_ROWS threshold and ragged splits.
        for &(m, k, n) in &[(1, 3, 2), (16, 16, 16), (33, 17, 29), (70, 40, 55)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let c_seq = seq.matmul(&a, &b);
            let c_par = par.matmul(&a, &b);
            assert_eq!(c_seq, c_par, "matmul {m}x{k}x{n} not bit-identical");
            let s_seq = seq.syrk_at_a(&a);
            let s_par = par.syrk_at_a(&a);
            assert_eq!(s_seq, s_par, "syrk {m}x{k} not bit-identical");
        }
    }

    #[test]
    fn workspace_recycles() {
        let mut ws = Workspace::new();
        let m1 = ws.take(4, 4);
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1);
        ws.put(m1);
        assert_eq!(ws.len(), 1);
        let m2 = ws.take(2, 6); // reshaped reuse: 12 elems fit in capacity 16
        assert_eq!(m2.shape(), (2, 6));
        assert!(ws.is_empty());
        assert_eq!(ws.allocations(), 1, "fitting reuse must not count as alloc");
    }

    #[test]
    fn workspace_prefers_fitting_buffer() {
        let mut ws = Workspace::new();
        let small = ws.take(2, 2);
        let big = ws.take(8, 8);
        ws.put(small);
        ws.put(big);
        assert_eq!(ws.allocations(), 2);
        // A 6x6 request skips the 2x2 buffer and reuses the 8x8 one.
        let m = ws.take(6, 6);
        assert_eq!(m.shape(), (6, 6));
        assert_eq!(ws.allocations(), 2);
        assert_eq!(ws.len(), 1);
        // Nothing fits 10x10: counts as an allocation (grown in place).
        let g = ws.take(10, 10);
        assert_eq!(g.shape(), (10, 10));
        assert_eq!(ws.allocations(), 3);
    }

    #[test]
    fn global_threads_roundtrip() {
        // Default is sequential; setting 1 keeps it sequential. (Setting >1
        // here would leak a pool into unrelated unit tests' timing, so the
        // parallel paths are covered by the local-engine tests above.)
        set_global_threads(1);
        assert_eq!(global_threads(), 1);
        assert_eq!(global_engine().threads(), 1);
    }
}
