//! Blocked GEMM and symmetric rank-k kernels.
//!
//! This is the O(n³) hot path of every Newton–Schulz-like iteration, so it is
//! the module the §Perf pass optimises. The current kernel (post-§Perf, see
//! EXPERIMENTS.md) is a **broadcast-FMA** design:
//!
//! * loop order (jc, kc, i, t, j) whose innermost loop is a dependence-free
//!   `c[j] += a·b[j]` stream — auto-vectorised to AVX-512 FMAs (dot-product
//!   reductions cannot be, without float-reassociation licence);
//! * a 4-row micro-tile so each B panel row read from L2 feeds four C rows;
//! * SYRK via rank-1 updates on the upper triangle, mirrored at the end.
//!
//! The previous packed dot-product kernel is kept as `gemm_packed` for the
//! ablation and as an independent implementation for cross-checking tests.
//!
//! GEMM-call counting: the PRISM paper reports costs in units of GEMMs; the
//! engines count their GEMM invocations through [`GemmCounter`].

use super::Mat;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global GEMM counter (process-wide, cheap relaxed atomics). The iteration
/// logs snapshot it before/after so per-algorithm GEMM counts can be reported
/// exactly as the paper does.
static GEMM_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_FLOPS: AtomicU64 = AtomicU64::new(0);

pub struct GemmCounter;

impl GemmCounter {
    pub fn calls() -> u64 {
        GEMM_CALLS.load(Ordering::Relaxed)
    }
    pub fn flops() -> u64 {
        GEMM_FLOPS.load(Ordering::Relaxed)
    }
    fn record(m: usize, n: usize, k: usize) {
        GEMM_CALLS.fetch_add(1, Ordering::Relaxed);
        GEMM_FLOPS.fetch_add((2 * m * n * k) as u64, Ordering::Relaxed);
    }
}

const MC: usize = 64; // rows of A per block
const KC: usize = 256; // shared dim per block

/// `C = A · B`.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul: {:?} x {:?}", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.cols();
    GemmCounter::record(m, n, k);
    let mut c = Mat::zeros(m, n);
    gemm_broadcast(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
    c
}

/// `C = Aᵀ · B` (one O(mk) transpose, then the broadcast kernel).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: {:?}ᵀ x {:?}", a.shape(), b.shape());
    let at = a.transpose();
    let (m, k) = at.shape();
    let n = b.cols();
    GemmCounter::record(m, n, k);
    let mut c = Mat::zeros(m, n);
    gemm_broadcast(at.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
    c
}

/// `C = A · Bᵀ` (one O(nk) transpose, then the broadcast kernel).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: {:?} x {:?}ᵀ", a.shape(), b.shape());
    let (m, k) = a.shape();
    let n = b.rows();
    GemmCounter::record(m, n, k);
    let bn = b.transpose();
    let mut c = Mat::zeros(m, n);
    gemm_broadcast(a.as_slice(), bn.as_slice(), c.as_mut_slice(), m, n, k);
    c
}

/// Symmetric rank-k: `C = Aᵀ A` (exactly symmetric by construction).
///
/// Rank-1 accumulation over rows of A: for each row `r`,
/// `C[i, i..] += r[i]·r[i..]` — the inner stream is contiguous and
/// dependence-free, so it vectorises like the GEMM kernel (§Perf change 3;
/// the old dot-product triangle ran at half the broadcast kernel's rate).
pub fn syrk_at_a(a: &Mat) -> Mat {
    let (k, n) = a.shape();
    GemmCounter::record(n, n, k);
    let mut c = Mat::zeros(n, n);
    {
        let cs = c.as_mut_slice();
        for t in 0..k {
            let row = a.row(t);
            for i in 0..n {
                let av = row[i];
                let (ci, ri) = (&mut cs[i * n + i..(i + 1) * n], &row[i..]);
                for (cv, rv) in ci.iter_mut().zip(ri) {
                    *cv += av * rv;
                }
            }
        }
    }
    mirror_upper(&mut c);
    c
}

/// Symmetric rank-k: `C = A Aᵀ` (via the same rank-1 kernel on Aᵀ's rows,
/// i.e. A's columns — one O(mk) transpose keeps the hot loop contiguous).
pub fn syrk_a_at(a: &Mat) -> Mat {
    let (m, k) = a.shape();
    GemmCounter::record(m, m, k);
    let at = a.transpose(); // k x m
    let mut c = Mat::zeros(m, m);
    {
        let cs = c.as_mut_slice();
        for t in 0..k {
            let row = at.row(t);
            for i in 0..m {
                let av = row[i];
                let (ci, ri) = (&mut cs[i * m + i..(i + 1) * m], &row[i..]);
                for (cv, rv) in ci.iter_mut().zip(ri) {
                    *cv += av * rv;
                }
            }
        }
    }
    mirror_upper(&mut c);
    c
}

/// Copy the upper triangle into the lower one (exact symmetry).
fn mirror_upper(c: &mut Mat) {
    let n = c.rows();
    for i in 1..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
}

/// Broadcast-FMA kernel: `C[m x n] += A[m x k] · B[k x n]`, both row-major.
///
/// Loop order (jc, kc, i, t, j): the innermost `crow[j] += a_it * brow[j]`
/// has no cross-iteration dependence, so rustc vectorises it into AVX-512
/// FMAs (a dot-product reduction kernel cannot be auto-vectorised without
/// float-reassociation licence). The (KC2 × NC) B panel stays hot in L2
/// across the whole i sweep, and each C row segment stays in L1 across the
/// t loop. §Perf change 2: 1.6–2.4x over the packed dot-product kernel.
fn gemm_broadcast(a: &[f64], b: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    const NC: usize = 512; // B-panel columns (NC·KC2·8B = 512 KiB ≤ L2)
    const KC2: usize = 256; // B-panel rows
    for j0 in (0..n).step_by(NC) {
        let j1 = (j0 + NC).min(n);
        for k0 in (0..k).step_by(KC2) {
            let k1 = (k0 + KC2).min(k);
            // 4-row micro-tile: each B row loaded from L2 feeds four C rows'
            // FMA streams (§Perf changes 4/5 — B bandwidth quartered).
            let mut i = 0;
            while i + 4 <= m {
                let (rows01, rows23) = (&mut c[i * n..(i + 4) * n]).split_at_mut(2 * n);
                let (row0, row1) = rows01.split_at_mut(n);
                let (row2, row3) = rows23.split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let c2 = &mut row2[j0..j1];
                let c3 = &mut row3[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for t in k0..k1 {
                    let (av0, av1, av2, av3) = (a0[t], a1[t], a2[t], a3[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((((c0v, c1v), c2v), c3v), bv) in c0
                        .iter_mut()
                        .zip(c1.iter_mut())
                        .zip(c2.iter_mut())
                        .zip(c3.iter_mut())
                        .zip(brow)
                    {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                        *c2v += av2 * bv;
                        *c3v += av3 * bv;
                    }
                }
                i += 4;
            }
            while i + 2 <= m {
                let (row0, row1) = (&mut c[i * n..(i + 2) * n]).split_at_mut(n);
                let c0 = &mut row0[j0..j1];
                let c1 = &mut row1[j0..j1];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                for t in k0..k1 {
                    let (av0, av1) = (a0[t], a1[t]);
                    let brow = &b[t * n + j0..t * n + j1];
                    for ((c0v, c1v), bv) in c0.iter_mut().zip(c1.iter_mut()).zip(brow) {
                        *c0v += av0 * bv;
                        *c1v += av1 * bv;
                    }
                }
                i += 2;
            }
            if i < m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for t in k0..k1 {
                    let av = a[i * k + t];
                    let brow = &b[t * n + j0..t * n + j1];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// Former core kernel (packed dot-product): kept for the §Perf ablation and
/// as a second implementation the property tests cross-check against.
#[allow(dead_code)]
pub(crate) fn gemm_packed(a: &[f64], bt: &[f64], c: &mut [f64], m: usize, n: usize, k: usize) {
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a[i * k + k0..i * k + k1];
                let crow = &mut c[i * n..(i + 1) * n];
                let mut j = 0;
                // 2-column unroll: amortises the A-row reload.
                while j + 2 <= n {
                    let b0 = &bt[j * k + k0..j * k + k1];
                    let b1 = &bt[(j + 1) * k + k0..(j + 1) * k + k1];
                    let (mut s0a, mut s0b) = (0.0, 0.0);
                    let (mut s1a, mut s1b) = (0.0, 0.0);
                    let len = arow.len();
                    let mut t = 0;
                    while t + 2 <= len {
                        s0a += arow[t] * b0[t];
                        s0b += arow[t + 1] * b0[t + 1];
                        s1a += arow[t] * b1[t];
                        s1b += arow[t + 1] * b1[t + 1];
                        t += 2;
                    }
                    while t < len {
                        s0a += arow[t] * b0[t];
                        s1a += arow[t] * b1[t];
                        t += 1;
                    }
                    crow[j] += s0a + s0b;
                    crow[j + 1] += s1a + s1b;
                    j += 2;
                }
                while j < n {
                    let brow = &bt[j * k + k0..j * k + k1];
                    let mut acc = 0.0;
                    for t in 0..arow.len() {
                        acc += arow[t] * brow[t];
                    }
                    crow[j] += acc;
                    j += 1;
                }
            }
        }
    }
}

/// Reference (naive) matmul for tests.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for t in 0..k {
            let av = a[(i, t)];
            for j in 0..n {
                c[(i, j)] += av * b[(t, j)];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: &Mat, b: &Mat, tol: f64) -> bool {
        a.shape() == b.shape() && a.sub(b).max_abs() < tol
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 13, 9), (64, 64, 64), (65, 130, 33)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            assert!(close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-10), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::gaussian(&mut rng, 20, 20, 1.0);
        assert!(close(&matmul(&a, &Mat::eye(20)), &a, 1e-12));
        assert!(close(&matmul(&Mat::eye(20), &a), &a, 1e-12));
    }

    #[test]
    fn at_b_and_a_bt_match() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::gaussian(&mut rng, 12, 7, 1.0);
        let b = Mat::gaussian(&mut rng, 12, 9, 1.0);
        let want = matmul_naive(&a.transpose(), &b);
        assert!(close(&matmul_at_b(&a, &b), &want, 1e-10));

        let c = Mat::gaussian(&mut rng, 9, 7, 1.0);
        let want2 = matmul_naive(&a, &c.transpose());
        assert!(close(&matmul_a_bt(&a, &c), &want2, 1e-10));
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::gaussian(&mut rng, 15, 8, 1.0);
        let want = matmul_naive(&a.transpose(), &a);
        let got = syrk_at_a(&a);
        assert!(close(&got, &want, 1e-10));
        assert_eq!(got.symmetry_defect(), 0.0);

        let want2 = matmul_naive(&a, &a.transpose());
        let got2 = syrk_a_at(&a);
        assert!(close(&got2, &want2, 1e-10));
        assert_eq!(got2.symmetry_defect(), 0.0);
    }

    #[test]
    fn gemm_counter_increments() {
        let before = GemmCounter::calls();
        let mut rng = Rng::seed_from(5);
        let a = Mat::gaussian(&mut rng, 4, 4, 1.0);
        let _ = matmul(&a, &a);
        assert!(GemmCounter::calls() > before);
        assert!(GemmCounter::flops() > 0);
    }
}
